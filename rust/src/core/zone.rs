//! Per-segment **zone metadata**: marginal-moment min/max per order plus
//! sketch-norm maxima, the cheap per-segment summary the pruned top-k
//! scan bounds distances with before touching a single panel.
//!
//! The paper's decomposition writes every even-p distance as two
//! marginal norms plus p−1 projected inner products:
//!
//! ```text
//! d̂(q, y) = Σq^p + Σy^p + (1/k) Σ_{m=1}^{p-1} c_m ⟨u_m(q), v_{p-m}(y)⟩
//! ```
//!
//! For a whole segment, `Σy^p ≥ min_moment[p]` and (Cauchy–Schwarz)
//! `|⟨u_m(q), v_{p−m}(y)⟩| ≤ ‖u_m(q)‖₂ · max_v2[p−m]`, so an admissible
//! lower bound on *every* row's estimated distance is computable from
//! this O(nm + orders) summary alone — see
//! [`crate::core::estimator::zone_lower_bound`] for the bound itself and
//! the deflation margin that keeps it admissible under fp rounding.
//!
//! Zones are **p-independent** (they summarize all moment orders and all
//! sketch orders the block carries), computed once at segment insertion
//! ([`ZoneMeta::from_block`]) and merged *exactly* at compaction
//! ([`ZoneMeta::merge`] — elementwise min/max selects input values, so a
//! merged zone is bitwise-identical to recomputing over the
//! concatenated block, with no O(rows·orders·k) rescan).

// Serving path: clippy backs the pallas-lint serving-no-panic rule.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use crate::core::quant::dot_views;
use crate::projection::sketcher::ColumnarBlock;

/// Zone summary of one columnar segment. All vectors are order-indexed
/// from 1 (`min_moment[o-1]` summarizes moment order `o`).
#[derive(Clone, Debug, PartialEq)]
pub struct ZoneMeta {
    /// Rows summarized (must equal the segment block's row count).
    pub rows: usize,
    /// Per moment order `o = 1..=nm`: min over rows of Σ x^o.
    pub min_moment: Vec<f64>,
    /// Per moment order `o = 1..=nm`: max over rows of Σ x^o.
    pub max_moment: Vec<f64>,
    /// Per sketch order `m = 1..=orders`: max over rows of ‖u_m‖₂.
    pub max_u2: Vec<f64>,
    /// Per sketch order `m = 1..=orders`: max over rows of ‖v_m‖₂.
    /// Equals `max_u2` for one-sided (basic-strategy) blocks, where the
    /// sides coincide.
    pub max_v2: Vec<f64>,
}

/// Fold-min that ignores NaN (f64::min semantics) — folding from +∞
/// selects an input value, so the fold is associative and a merge of
/// per-block folds is bitwise-identical to one fold over all rows.
#[inline]
fn fold_min(acc: f64, v: f64) -> f64 {
    acc.min(v)
}

#[inline]
fn fold_max(acc: f64, v: f64) -> f64 {
    acc.max(v)
}

impl ZoneMeta {
    /// Summarize a columnar block: one pass over its moments and one
    /// self-dot per (row, order, side). O(rows · (nm + orders·k)) —
    /// done once per segment at ingest/seal, never on the query path.
    pub fn from_block(block: &ColumnarBlock) -> ZoneMeta {
        let nm = block.moment_orders();
        let orders = block.orders();
        let rows = block.rows();
        let mut min_moment = vec![f64::INFINITY; nm];
        let mut max_moment = vec![f64::NEG_INFINITY; nm];
        for r in 0..rows {
            let mrow = block.moments_row(r);
            for (o, &v) in mrow.iter().enumerate() {
                min_moment[o] = fold_min(min_moment[o], v);
                max_moment[o] = fold_max(max_moment[o], v);
            }
        }
        // Views decode quantized panels to their exact stored values, so
        // a zone computed from an encoded block bounds exactly the
        // values the estimator kernels will see (admissibility is
        // independent of the panel encoding).
        let mut max_u2 = vec![f64::NEG_INFINITY; orders];
        let mut max_v2 = vec![f64::NEG_INFINITY; orders];
        for m in 1..=orders {
            for r in 0..rows {
                let u = block.u_view(m, r);
                max_u2[m - 1] = fold_max(max_u2[m - 1], dot_views(u, u).sqrt());
                let v = block.v_view(m, r);
                max_v2[m - 1] = fold_max(max_v2[m - 1], dot_views(v, v).sqrt());
            }
        }
        ZoneMeta { rows, min_moment, max_moment, max_u2, max_v2 }
    }

    /// Merge zones of segments being compacted into the zone of the
    /// merged segment. Elementwise min/max selects one of the input
    /// values, so the result is **bitwise-identical** to
    /// [`ZoneMeta::from_block`] over the concatenated block — no panel
    /// rescan at compaction. Panics on empty input or shape mismatch
    /// (compaction groups are homogeneous by construction).
    pub fn merge(zones: &[&ZoneMeta]) -> ZoneMeta {
        assert!(!zones.is_empty(), "zone merge of zero segments");
        let first = zones[0];
        let (nm, orders) = (first.min_moment.len(), first.max_u2.len());
        let mut out = ZoneMeta {
            rows: 0,
            min_moment: vec![f64::INFINITY; nm],
            max_moment: vec![f64::NEG_INFINITY; nm],
            max_u2: vec![f64::NEG_INFINITY; orders],
            max_v2: vec![f64::NEG_INFINITY; orders],
        };
        for z in zones {
            assert!(
                z.min_moment.len() == nm && z.max_u2.len() == orders,
                "heterogeneous zones in merge"
            );
            out.rows += z.rows;
            for o in 0..nm {
                out.min_moment[o] = fold_min(out.min_moment[o], z.min_moment[o]);
                out.max_moment[o] = fold_max(out.max_moment[o], z.max_moment[o]);
            }
            for m in 0..orders {
                out.max_u2[m] = fold_max(out.max_u2[m], z.max_u2[m]);
                out.max_v2[m] = fold_max(out.max_v2[m], z.max_v2[m]);
            }
        }
        out
    }

    /// f64 word count of the persisted encoding for a given shape — the
    /// length codecs must validate *before* allocating ([`zone_len`] is
    /// the value a well-formed file declares).
    pub fn encoded_len(nm: usize, orders: usize, two_sided: bool) -> usize {
        2 * nm + orders * if two_sided { 2 } else { 1 }
    }

    /// Flatten for persistence: `min_moment · max_moment · max_u2`
    /// (`· max_v2` only when two-sided — one-sided blocks' v side is a
    /// bitwise copy of the u side and is reconstructed on decode).
    pub fn to_f64s(&self, two_sided: bool) -> Vec<f64> {
        let mut out =
            Vec::with_capacity(Self::encoded_len(self.min_moment.len(), self.max_u2.len(), two_sided));
        out.extend_from_slice(&self.min_moment);
        out.extend_from_slice(&self.max_moment);
        out.extend_from_slice(&self.max_u2);
        if two_sided {
            out.extend_from_slice(&self.max_v2);
        }
        out
    }

    /// Decode a persisted zone. `vals` must be exactly
    /// [`ZoneMeta::encoded_len`] words — callers validate the declared
    /// length against the shape *before* reading/allocating the buffer;
    /// this re-checks and errors (never panics) on mismatch.
    pub fn from_f64s(
        rows: usize,
        nm: usize,
        orders: usize,
        two_sided: bool,
        vals: &[f64],
    ) -> anyhow::Result<ZoneMeta> {
        anyhow::ensure!(
            vals.len() == Self::encoded_len(nm, orders, two_sided),
            "zone payload of {} words does not match shape (nm={nm}, orders={orders}, \
             two_sided={two_sided})",
            vals.len()
        );
        let min_moment = vals[..nm].to_vec();
        let max_moment = vals[nm..2 * nm].to_vec();
        let max_u2 = vals[2 * nm..2 * nm + orders].to_vec();
        let max_v2 = if two_sided {
            vals[2 * nm + orders..].to_vec()
        } else {
            max_u2.clone()
        };
        Ok(ZoneMeta { rows, min_moment, max_moment, max_u2, max_v2 })
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::core::estimator::dot;
    use crate::projection::sketcher::Sketcher;
    use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};

    fn block_of(strategy: Strategy, p: usize, k: usize, n: usize, seed: u64) -> ColumnarBlock {
        let sk = Sketcher::new(ProjectionSpec::new(seed, k, ProjectionDist::Normal, strategy), p);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|i| (0..20).map(|t| ((i * 13 + t) as f32 * 0.21).sin()).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        sk.sketch_block(&refs, 1)
    }

    #[test]
    fn from_block_bounds_every_row() {
        for (strategy, p) in [(Strategy::Basic, 4), (Strategy::Alternative, 6)] {
            let block = block_of(strategy, p, 8, 9, 3);
            let z = ZoneMeta::from_block(&block);
            assert_eq!(z.rows, 9);
            assert_eq!(z.min_moment.len(), 2 * (p - 1));
            assert_eq!(z.max_u2.len(), p - 1);
            for r in 0..block.rows() {
                for o in 1..=block.moment_orders() {
                    let v = block.moment(r, o);
                    assert!(z.min_moment[o - 1] <= v && v <= z.max_moment[o - 1], "o={o} r={r}");
                }
                for m in 1..=block.orders() {
                    let u = block.u_row(m, r);
                    assert!(dot(u, u).sqrt() <= z.max_u2[m - 1], "u m={m} r={r}");
                    let v = block.v_row(m, r);
                    assert!(dot(v, v).sqrt() <= z.max_v2[m - 1], "v m={m} r={r}");
                }
            }
            // One-sided blocks: the v bound IS the u bound, bitwise.
            if !block.is_two_sided() {
                assert_eq!(z.max_u2, z.max_v2);
            }
        }
    }

    #[test]
    fn merge_is_bitwise_identical_to_recomputation_over_concat() {
        for strategy in [Strategy::Basic, Strategy::Alternative] {
            let a = block_of(strategy, 4, 8, 5, 7);
            let b = block_of(strategy, 4, 8, 3, 8);
            let c = block_of(strategy, 4, 8, 1, 9);
            let za = ZoneMeta::from_block(&a);
            let zb = ZoneMeta::from_block(&b);
            let zc = ZoneMeta::from_block(&c);
            let merged = ZoneMeta::merge(&[&za, &zb, &zc]);
            let whole = ZoneMeta::from_block(&ColumnarBlock::concat(&[&a, &b, &c]));
            assert_eq!(merged, whole, "{strategy:?}");
        }
    }

    #[test]
    fn zones_of_encoded_blocks_bound_their_decoded_values() {
        use crate::core::quant::PanelQuant;
        for q in [PanelQuant::F16, PanelQuant::Bf16, PanelQuant::I8] {
            let block = block_of(Strategy::Alternative, 4, 8, 6, 5).encoded_as(q);
            let z = ZoneMeta::from_block(&block);
            for r in 0..block.rows() {
                for m in 1..=block.orders() {
                    let u = block.u_view(m, r);
                    assert!(dot_views(u, u).sqrt() <= z.max_u2[m - 1], "{q:?} u m={m} r={r}");
                    let v = block.v_view(m, r);
                    assert!(dot_views(v, v).sqrt() <= z.max_v2[m - 1], "{q:?} v m={m} r={r}");
                }
            }
            // Compaction invariant holds per encoding too: merged zone ==
            // recomputed zone over the concatenated block, bitwise —
            // whether concat stayed encoded (f16/bf16) or fell back to
            // the decoded f32 domain (i8 scale mismatch).
            let b2 = block_of(Strategy::Alternative, 4, 8, 3, 6).encoded_as(q);
            let merged =
                ZoneMeta::merge(&[&ZoneMeta::from_block(&block), &ZoneMeta::from_block(&b2)]);
            let whole = ZoneMeta::from_block(&ColumnarBlock::concat(&[&block, &b2]));
            assert_eq!(merged, whole, "{q:?}");
        }
    }

    #[test]
    fn codec_roundtrips_both_sidednesses() {
        for (strategy, two_sided) in [(Strategy::Basic, false), (Strategy::Alternative, true)] {
            let block = block_of(strategy, 4, 8, 4, 11);
            assert_eq!(block.is_two_sided(), two_sided);
            let z = ZoneMeta::from_block(&block);
            let flat = z.to_f64s(two_sided);
            assert_eq!(flat.len(), ZoneMeta::encoded_len(6, 3, two_sided));
            let back = ZoneMeta::from_f64s(4, 6, 3, two_sided, &flat).unwrap();
            assert_eq!(back, z);
        }
    }

    #[test]
    fn codec_rejects_wrong_lengths() {
        let z = ZoneMeta::from_block(&block_of(Strategy::Basic, 4, 8, 2, 13));
        let flat = z.to_f64s(false);
        assert!(ZoneMeta::from_f64s(2, 6, 3, false, &flat[..flat.len() - 1]).is_err());
        assert!(ZoneMeta::from_f64s(2, 6, 3, true, &flat).is_err());
        let mut long = flat.clone();
        long.push(0.0);
        assert!(ZoneMeta::from_f64s(2, 6, 3, false, &long).is_err());
    }

    #[test]
    #[should_panic(expected = "zone merge of zero segments")]
    fn merge_of_nothing_panics() {
        let _ = ZoneMeta::merge(&[]);
    }
}
