//! Bundled mini text corpus → term-frequency vectors.
//!
//! The paper's intro motivates l_p distances over massive *non-negative,
//! heavy-tailed* data — the canonical example being term-frequency (TF)
//! document vectors. We bundle a small synthetic corpus (topic-mixed
//! documents over a shared vocabulary) so the k-NN example (E8) and the
//! pipeline examples run on "real-shaped" data without network access.
//!
//! Documents are generated from a seeded topic model: each topic is a
//! Zipf-weighted distribution over a vocabulary slice, each document
//! mixes 1–2 topics. This mirrors the skew (a few very frequent terms,
//! a long tail) that makes the fourth-moment (kurtosis-driven) distances
//! of the paper interesting. Hash-TF folds tokens into `d` buckets, the
//! standard trick for fixed-width vectors from unbounded vocabularies.

use super::matrix::RowMatrix;
use crate::util::rng::Rng;

/// Vocabulary size of the synthetic corpus (before hash folding).
pub const VOCAB: usize = 4096;
/// Number of topics documents are mixed from.
pub const TOPICS: usize = 8;

/// A corpus as document labels + TF matrix.
pub struct Corpus {
    /// Dominant topic of each document (ground truth for k-NN recall).
    pub labels: Vec<usize>,
    /// (n × d) term-frequency matrix, hash-folded to d buckets.
    pub tf: RowMatrix,
}

/// Zipf sampler over `n` ranks with exponent `s` via inverse-CDF on a
/// precomputed table (fast enough at corpus scale, exact distribution).
struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for r in 1..=n {
            acc += 1.0 / (r as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        // Binary search for the first cdf entry >= u.
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

/// Deterministic token hash (splitmix-style) → bucket in `[0, d)`.
fn fold(token: usize, d: usize) -> usize {
    let mut z = token as u64 ^ 0x9e37_79b9_7f4a_7c15;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    (z ^ (z >> 31)) as usize % d
}

/// Generate the bundled corpus: `n` documents, TF vectors hash-folded to
/// `d` dimensions, average document length `doc_len` tokens.
///
/// Deterministic in `seed`. Returned TF counts are raw (not normalized) —
/// the heavy-tailed integer counts are precisely the regime where higher
/// moments dominate and p > 2 distances separate documents that l_1/l_2
/// cannot (paper §1, ICA/kurtosis motivation).
pub fn generate(n: usize, d: usize, doc_len: usize, seed: u64) -> Corpus {
    let mut rng = Rng::new(seed ^ CORPUS_TAG);
    // Each topic owns a Zipf distribution over a rotated vocabulary slice,
    // so topics share the global head but differ in the tail.
    let zipf = Zipf::new(VOCAB, 1.2);
    let mut tf = RowMatrix::zeros(n, d);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let main_topic = rng.next_range(TOPICS);
        // 30% of documents blend a secondary topic (harder k-NN cases).
        let alt_topic = if rng.next_f64() < 0.3 { rng.next_range(TOPICS) } else { main_topic };
        labels.push(main_topic);
        let len = doc_len / 2 + rng.next_range(doc_len);
        let row = tf.row_mut(i);
        for _ in 0..len {
            let topic = if rng.next_f64() < 0.8 { main_topic } else { alt_topic };
            let rank = zipf.sample(&mut rng);
            // Topic rotation: same rank maps to a different token per topic.
            let token = (rank + topic * (VOCAB / TOPICS)) % VOCAB;
            row[fold(token, d)] += 1.0;
        }
    }
    Corpus { labels, tf }
}

/// Domain-separation tag so corpus seeds never collide with generator
/// seeds used elsewhere.
const CORPUS_TAG: u64 = 0xc0de_c04b_0000_0001;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let a = generate(16, 64, 40, 7);
        let b = generate(16, 64, 40, 7);
        assert_eq!(a.tf.data(), b.tf.data());
        assert_eq!(a.labels, b.labels);
        let c = generate(16, 64, 40, 8);
        assert_ne!(a.tf.data(), c.tf.data());
    }

    #[test]
    fn non_negative_and_heavy_tailed() {
        let c = generate(64, 256, 100, 1);
        assert!(c.tf.data().iter().all(|&v| v >= 0.0));
        // Heavy tail: max bucket count well above the mean count.
        let total: f32 = c.tf.data().iter().sum();
        let mean = total / c.tf.data().len() as f32;
        let max = c.tf.data().iter().cloned().fold(0.0, f32::max);
        assert!(max > 8.0 * mean, "max={max} mean={mean}");
    }

    #[test]
    fn labels_cover_topics() {
        let c = generate(256, 128, 60, 3);
        let mut seen = [false; TOPICS];
        for &l in &c.labels {
            assert!(l < TOPICS);
            seen[l] = true;
        }
        assert!(seen.iter().all(|&s| s), "all topics should appear at n=256");
    }

    #[test]
    fn zipf_is_skewed() {
        let z = Zipf::new(1000, 1.2);
        let mut rng = Rng::new(9);
        let mut head = 0usize;
        let n = 20_000;
        for _ in 0..n {
            if z.sample(&mut rng) < 10 {
                head += 1;
            }
        }
        // Top-10 ranks carry far more than 10/1000 of the mass.
        assert!(head as f64 / n as f64 > 0.2, "head mass {head}/{n}");
    }
}
