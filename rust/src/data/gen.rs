//! Synthetic data generators covering the paper's data regimes.
//!
//! The paper's motivating data are **non-negative, heavy-tailed**
//! (term-frequency / count matrices, §2.2 "the data are non-negative,
//! which is more likely the reality"); the Δ₄ sign-flip discussion also
//! needs signed data. Generators:
//!
//! * `Uniform01` — dense non-negative, light tails.
//! * `ZipfTf` — sparse term-frequency-like rows: zipf-ranked column
//!   popularity × geometric counts (the nearest synthetic equivalent of
//!   the web/text matrices the paper targets; substitution documented in
//!   DESIGN.md §3).
//! * `LogNormal` — dense non-negative, heavy tails (kurtosis-rich, the
//!   ICA/4th-moment motivation).
//! * `Gaussian` — signed, for the general-formula experiments.
//! * `SignedSplit` — x-rows negative, y-rows positive: the paper's
//!   explicit Δ₄ ≥ 0 adversarial case (§2.2).

use super::matrix::RowMatrix;
use crate::util::normal::NormalSampler;
use crate::util::rng::Rng;

/// Data distribution families.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DataDist {
    Uniform01,
    ZipfTf { exponent: f64, density: f64 },
    LogNormal { sigma: f64 },
    Gaussian,
    SignedSplit,
}

impl DataDist {
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        Ok(match text {
            "uniform" => DataDist::Uniform01,
            "zipf" => DataDist::ZipfTf { exponent: 1.1, density: 0.1 },
            "lognormal" => DataDist::LogNormal { sigma: 1.0 },
            "gaussian" => DataDist::Gaussian,
            "signed-split" => DataDist::SignedSplit,
            _ => anyhow::bail!(
                "unknown data distribution {text:?} (uniform|zipf|lognormal|gaussian|signed-split)"
            ),
        })
    }

    pub fn describe(&self) -> &'static str {
        match self {
            DataDist::Uniform01 => "uniform",
            DataDist::ZipfTf { .. } => "zipf",
            DataDist::LogNormal { .. } => "lognormal",
            DataDist::Gaussian => "gaussian",
            DataDist::SignedSplit => "signed-split",
        }
    }

    /// All rows non-negative? (Determines which strategy Lemma 3 favors.)
    pub fn non_negative(&self) -> bool {
        matches!(
            self,
            DataDist::Uniform01 | DataDist::ZipfTf { .. } | DataDist::LogNormal { .. }
        )
    }
}

/// Generate an n×d matrix from `dist` with deterministic `seed`.
pub fn generate(dist: DataDist, n: usize, d: usize, seed: u64) -> RowMatrix {
    let mut rng = Rng::new(seed ^ 0xDA7A_5EED);
    let mut normal = NormalSampler::from_rng(rng.fork(1));
    let mut m = RowMatrix::zeros(n, d);
    match dist {
        DataDist::Uniform01 => {
            for i in 0..n {
                for v in m.row_mut(i) {
                    *v = rng.next_f64() as f32;
                }
            }
        }
        DataDist::Gaussian => {
            for i in 0..n {
                for v in m.row_mut(i) {
                    *v = normal.sample() as f32;
                }
            }
        }
        DataDist::LogNormal { sigma } => {
            for i in 0..n {
                for v in m.row_mut(i) {
                    // exp(σZ - σ²/2): mean 1, heavy right tail.
                    *v = (sigma * normal.sample() - sigma * sigma / 2.0).exp() as f32;
                }
            }
        }
        DataDist::ZipfTf { exponent, density } => {
            // Column j has zipf weight (j+1)^-exponent; each row activates
            // ~density·d columns with geometric "term counts" scaled by
            // the column weight — a TF-matrix lookalike.
            let weights: Vec<f64> =
                (0..d).map(|j| ((j + 1) as f64).powf(-exponent)).collect();
            let nnz = ((d as f64 * density).ceil() as usize).max(1).min(d);
            let mut cols: Vec<usize> = (0..d).collect();
            for i in 0..n {
                // Zipf-biased column choice: earlier columns more likely.
                rng.shuffle(&mut cols);
                let mut picked = 0;
                let mut ci = 0;
                let row = m.row_mut(i);
                while picked < nnz && ci < d {
                    let j = cols[ci];
                    ci += 1;
                    // accept with probability ∝ zipf weight (capped at 1)
                    if rng.next_f64() < (weights[j] * 10.0).min(1.0) {
                        // geometric count 1,2,3,… (mean 2)
                        let mut c = 1.0;
                        while rng.next_f64() < 0.5 {
                            c += 1.0;
                        }
                        row[j] = c as f32;
                        picked += 1;
                    }
                }
                // guarantee at least one nonzero
                if picked == 0 {
                    row[cols[0]] = 1.0;
                }
            }
        }
        DataDist::SignedSplit => {
            // Even rows all-negative, odd rows all-positive — pairing an
            // even with an odd row realizes the paper's Δ₄ ≥ 0 case.
            for i in 0..n {
                let sign = if i % 2 == 0 { -1.0 } else { 1.0 };
                for v in m.row_mut(i) {
                    *v = (sign * (0.05 + rng.next_f64())) as f32;
                }
            }
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(DataDist::Uniform01, 4, 16, 9);
        let b = generate(DataDist::Uniform01, 4, 16, 9);
        let c = generate(DataDist::Uniform01, 4, 16, 10);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn non_negative_families_are_non_negative() {
        for dist in [
            DataDist::Uniform01,
            DataDist::ZipfTf { exponent: 1.1, density: 0.1 },
            DataDist::LogNormal { sigma: 1.0 },
        ] {
            let m = generate(dist, 8, 64, 3);
            assert!(m.data().iter().all(|&v| v >= 0.0), "{dist:?}");
            assert!(dist.non_negative());
        }
    }

    #[test]
    fn zipf_rows_sparse_and_nonzero() {
        let m = generate(DataDist::ZipfTf { exponent: 1.1, density: 0.05 }, 16, 256, 4);
        for i in 0..16 {
            let nnz = m.row(i).iter().filter(|&&v| v != 0.0).count();
            assert!(nnz >= 1, "row {i} empty");
            assert!(nnz <= 64, "row {i} too dense: {nnz}");
        }
    }

    #[test]
    fn signed_split_signs() {
        let m = generate(DataDist::SignedSplit, 4, 32, 5);
        assert!(m.row(0).iter().all(|&v| v < 0.0));
        assert!(m.row(1).iter().all(|&v| v > 0.0));
        assert!(!DataDist::SignedSplit.non_negative());
    }

    #[test]
    fn lognormal_heavy_tail() {
        let m = generate(DataDist::LogNormal { sigma: 1.5 }, 1, 20_000, 6);
        let mean: f64 = m.row(0).iter().map(|&v| v as f64).sum::<f64>() / 20_000.0;
        let max = m.row(0).iter().cloned().fold(0.0f32, f32::max) as f64;
        assert!(max / mean > 20.0, "tail not heavy: max/mean={}", max / mean);
    }

    #[test]
    fn parse_names() {
        assert_eq!(DataDist::parse("zipf").unwrap().describe(), "zipf");
        assert!(DataDist::parse("bogus").is_err());
    }
}
