//! Matrix IO: a tiny binary f32 format (magic + dims, little-endian) and
//! CSV for interoperability.

use super::matrix::RowMatrix;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"LPSK";

/// Write the binary format: "LPSK" + n:u64le + d:u64le + n*d f32le.
pub fn write_binary(m: &RowMatrix, path: &Path) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&(m.n() as u64).to_le_bytes())?;
    w.write_all(&(m.d() as u64).to_le_bytes())?;
    for &v in m.data() {
        w.write_all(&v.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Read the binary format.
pub fn read_binary(path: &Path) -> anyhow::Result<RowMatrix> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "bad magic in {path:?}");
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let n = u64::from_le_bytes(b8) as usize;
    r.read_exact(&mut b8)?;
    let d = u64::from_le_bytes(b8) as usize;
    anyhow::ensure!(
        n.checked_mul(d).is_some() && n * d < (1 << 34),
        "unreasonable dims {n}x{d}"
    );
    let mut data = vec![0.0f32; n * d];
    let mut b4 = [0u8; 4];
    for v in data.iter_mut() {
        r.read_exact(&mut b4)?;
        *v = f32::from_le_bytes(b4);
    }
    Ok(RowMatrix::new(n, d, data))
}

/// Write CSV (no header, one row per line).
pub fn write_csv(m: &RowMatrix, path: &Path) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for i in 0..m.n() {
        let line: Vec<String> = m.row(i).iter().map(|v| format!("{v}")).collect();
        writeln!(w, "{}", line.join(","))?;
    }
    w.flush()?;
    Ok(())
}

/// Read CSV (no header; all rows must have equal width).
pub fn read_csv(path: &Path) -> anyhow::Result<RowMatrix> {
    let f = std::fs::File::open(path)?;
    let r = BufReader::new(f);
    let mut data = Vec::new();
    let mut d = None;
    let mut n = 0;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let vals: Vec<f32> = line
            .split(',')
            .map(|t| t.trim().parse::<f32>())
            .collect::<Result<_, _>>()
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        match d {
            None => d = Some(vals.len()),
            Some(w) => anyhow::ensure!(
                w == vals.len(),
                "ragged CSV: line {} has {} cols, expected {w}",
                lineno + 1,
                vals.len()
            ),
        }
        data.extend_from_slice(&vals);
        n += 1;
    }
    let d = d.ok_or_else(|| anyhow::anyhow!("empty CSV {path:?}"))?;
    Ok(RowMatrix::new(n, d, data))
}

/// Load either format by extension (.bin / .csv).
pub fn load(path: &Path) -> anyhow::Result<RowMatrix> {
    match path.extension().and_then(|e| e.to_str()) {
        Some("csv") => read_csv(path),
        _ => read_binary(path),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen::{generate, DataDist};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("lpsketch-io-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn binary_roundtrip() {
        let m = generate(DataDist::Gaussian, 7, 13, 1);
        let p = tmp("rt.bin");
        write_binary(&m, &p).unwrap();
        let back = read_binary(&p).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn csv_roundtrip() {
        let m = generate(DataDist::Uniform01, 3, 5, 2);
        let p = tmp("rt.csv");
        write_csv(&m, &p).unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(m.n(), back.n());
        assert_eq!(m.d(), back.d());
        for (a, b) in m.data().iter().zip(back.data()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("bad.bin");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(read_binary(&p).is_err());
    }

    #[test]
    fn ragged_csv_rejected() {
        let p = tmp("ragged.csv");
        std::fs::write(&p, "1,2,3\n4,5\n").unwrap();
        assert!(read_csv(&p).is_err());
    }

    #[test]
    fn load_dispatches_on_extension() {
        let m = generate(DataDist::Uniform01, 2, 4, 3);
        let pb = tmp("d.bin");
        let pc = tmp("d.csv");
        write_binary(&m, &pb).unwrap();
        write_csv(&m, &pc).unwrap();
        assert_eq!(load(&pb).unwrap().n(), 2);
        assert_eq!(load(&pc).unwrap().d(), 4);
    }
}
