//! Row-major data matrix A ∈ R^{n×D} and block iteration.

/// Dense row-major matrix of f32 (the paper's data matrix A).
#[derive(Clone, Debug, PartialEq)]
pub struct RowMatrix {
    n: usize,
    d: usize,
    data: Vec<f32>,
}

impl RowMatrix {
    pub fn new(n: usize, d: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), n * d, "data length must be n*d");
        RowMatrix { n, d, data }
    }

    pub fn zeros(n: usize, d: usize) -> Self {
        RowMatrix { n, d, data: vec![0.0; n * d] }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn d(&self) -> usize {
        self.d
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.d..(i + 1) * self.d]
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Row range [i0, i1) as a contiguous slice.
    pub fn rows(&self, i0: usize, i1: usize) -> &[f32] {
        &self.data[i0 * self.d..i1 * self.d]
    }

    /// Iterate blocks of up to `block_rows` rows: yields (row0, rows).
    pub fn blocks(&self, block_rows: usize) -> impl Iterator<Item = (usize, &[f32])> {
        assert!(block_rows > 0);
        (0..self.n).step_by(block_rows).map(move |i0| {
            let i1 = (i0 + block_rows).min(self.n);
            (i0, self.rows(i0, i1))
        })
    }

    /// Bytes of payload (storage accounting for E7).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// f64 copy of row i (theory-side helpers want f64).
    pub fn row_f64(&self, i: usize) -> Vec<f64> {
        self.row(i).iter().map(|&v| v as f64).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_and_blocks() {
        let m = RowMatrix::new(5, 3, (0..15).map(|i| i as f32).collect());
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
        let blocks: Vec<_> = m.blocks(2).collect();
        assert_eq!(blocks.len(), 3);
        assert_eq!(blocks[0].0, 0);
        assert_eq!(blocks[0].1.len(), 6);
        assert_eq!(blocks[2].0, 4);
        assert_eq!(blocks[2].1.len(), 3); // tail block
    }

    #[test]
    #[should_panic(expected = "n*d")]
    fn bad_shape_rejected() {
        RowMatrix::new(2, 3, vec![0.0; 5]);
    }

    #[test]
    fn bytes_accounting() {
        assert_eq!(RowMatrix::zeros(4, 8).bytes(), 128);
    }
}
