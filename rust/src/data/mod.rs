//! Data substrates: row-major matrices with block iterators, synthetic
//! generators for the paper's data regimes, binary/CSV IO, and a bundled
//! mini text corpus → term-frequency vectors (the motivating non-negative
//! heavy-tailed workload).

pub mod corpus;
pub mod gen;
pub mod io;
pub mod matrix;

pub use gen::DataDist;
pub use matrix::RowMatrix;
