//! Shared experiment machinery: workload pair builders, the Monte-Carlo
//! driver that every variance experiment uses, and acceptance helpers.
//!
//! The paper is a theory report — its "tables" are the Lemma variance
//! formulas. Each experiment therefore compares an *empirical* Monte-
//! Carlo moment against the corresponding *closed-form* prediction and
//! reports the ratio (acceptance: within MC error).

use crate::core::decompose::{exact_distance, Decomposition};
use crate::core::estimator;
use crate::core::mle::{self, Solve};
use crate::core::variance::{self, CrossTable};
use crate::data::{gen, DataDist};
use crate::projection::sketcher::Sketcher;
use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};
use crate::util::stats::Welford;

/// A fixed (x, y) pair with its exact quantities precomputed.
pub struct Pair {
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub x64: Vec<f64>,
    pub y64: Vec<f64>,
    pub exact: f64,
    pub table: CrossTable,
    pub p: usize,
}

impl Pair {
    pub fn new(x: Vec<f32>, y: Vec<f32>, p: usize) -> Self {
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let y64: Vec<f64> = y.iter().map(|&v| v as f64).collect();
        let exact = exact_distance(&x64, &y64, p);
        let table = variance::table_for(&x64, &y64, p);
        Pair { x, y, x64, y64, exact, table, p }
    }

    /// Draw a pair from a data distribution (rows 0 and 1 of a 2×D draw).
    pub fn from_dist(dist: DataDist, d: usize, p: usize, seed: u64) -> Self {
        let m = gen::generate(dist, 2, d, seed);
        Pair::new(m.row(0).to_vec(), m.row(1).to_vec(), p)
    }
}

/// What one Monte-Carlo sweep measured.
#[derive(Clone, Debug)]
pub struct McResult {
    pub k: usize,
    pub reps: usize,
    pub exact: f64,
    pub mc_mean: f64,
    pub mc_var: f64,
    pub theory_var: f64,
    /// z-score of the mean against the exact distance (|z| < ~4 ⇒
    /// consistent with unbiasedness).
    pub bias_z: f64,
}

impl McResult {
    pub fn var_ratio(&self) -> f64 {
        self.mc_var / self.theory_var
    }
}

/// Which estimator the MC driver runs.
#[derive(Clone, Copy, Debug)]
pub enum Estimator {
    Plain,
    Mle(Solve),
}

/// Monte-Carlo over projection seeds: sketch the pair `reps` times with
/// independent seeds, estimate, and compare moments to `theory_var`.
pub fn run_mc(
    pair: &Pair,
    strategy: Strategy,
    dist: ProjectionDist,
    k: usize,
    reps: usize,
    est: Estimator,
    theory_var: f64,
) -> McResult {
    let dec = Decomposition::new(pair.p).expect("valid p");
    let mut w = Welford::new();
    for rep in 0..reps {
        let spec = ProjectionSpec::new(0x9E1 ^ (rep as u64) << 8, k, dist, strategy);
        let sk = Sketcher::new(spec, pair.p);
        let rows = sk.sketch_rows(&[&pair.x, &pair.y]);
        let d = match est {
            Estimator::Plain => estimator::estimate(&dec, &rows[0], &rows[1]),
            Estimator::Mle(solve) => mle::estimate_mle(&dec, &rows[0], &rows[1], solve),
        };
        w.push(d);
    }
    McResult {
        k,
        reps,
        exact: pair.exact,
        mc_mean: w.mean(),
        mc_var: w.sample_variance(),
        theory_var,
        bias_z: w.z_against(pair.exact),
    }
}

/// The theory variance for a (strategy, dist) combination at width k —
/// dispatching to the right Lemma formula.
pub fn theory_var(pair: &Pair, strategy: Strategy, dist: ProjectionDist, k: usize) -> f64 {
    let s = dist.kurtosis();
    match strategy {
        Strategy::Basic => variance::var_basic_general(pair.p, s, &pair.table, k),
        Strategy::Alternative => variance::var_alt_general(pair.p, s, &pair.table, k),
    }
}

/// Standard data regimes the experiments sweep (name, dist).
pub fn data_regimes() -> Vec<(&'static str, DataDist)> {
    vec![
        ("uniform", DataDist::Uniform01),
        ("zipf-tf", DataDist::ZipfTf { exponent: 1.1, density: 0.1 }),
        ("lognormal", DataDist::LogNormal { sigma: 1.0 }),
        ("gaussian", DataDist::Gaussian),
    ]
}

/// MC tolerance on a variance ratio at `reps` replicates: the sampling
/// sd of a variance estimate is ≈ √(2/(reps−1)) (relative, Gaussian-ish
/// tails), padded ×5 for the heavy-tailed estimators here.
pub fn var_tolerance(reps: usize) -> f64 {
    5.0 * (2.0 / (reps as f64 - 1.0)).sqrt()
}

/// Acceptance record every experiment emits per configuration.
#[derive(Clone, Debug)]
pub struct Acceptance {
    pub label: String,
    pub ok: bool,
    pub detail: String,
}

impl Acceptance {
    pub fn check(label: impl Into<String>, ok: bool, detail: impl Into<String>) -> Self {
        Acceptance { label: label.into(), ok, detail: detail.into() }
    }
}

/// Render acceptances and return whether all passed.
pub fn report(acceptances: &[Acceptance]) -> bool {
    let mut all = true;
    for a in acceptances {
        let mark = if a.ok { "PASS" } else { "FAIL" };
        println!("  [{mark}] {} — {}", a.label, a.detail);
        all &= a.ok;
    }
    all
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_precomputes_exact() {
        let p = Pair::new(vec![1.0, 2.0], vec![0.0, 1.0], 4);
        assert_eq!(p.exact, 2.0); // 1^4 + 1^4
    }

    #[test]
    fn mc_driver_is_consistent_with_lemma1() {
        let pair = Pair::from_dist(DataDist::Uniform01, 48, 4, 3);
        let k = 24;
        let tv = theory_var(&pair, Strategy::Basic, ProjectionDist::Normal, k);
        let r = run_mc(
            &pair,
            Strategy::Basic,
            ProjectionDist::Normal,
            k,
            1500,
            Estimator::Plain,
            tv,
        );
        assert!(r.bias_z.abs() < 4.5, "bias z={}", r.bias_z);
        assert!(
            (r.var_ratio() - 1.0).abs() < var_tolerance(1500),
            "ratio={}",
            r.var_ratio()
        );
    }

    #[test]
    fn regimes_cover_signed_and_unsigned() {
        let regimes = data_regimes();
        assert!(regimes.iter().any(|(_, d)| d.non_negative()));
        assert!(regimes.iter().any(|(_, d)| !d.non_negative()));
    }
}
