//! E10 — "compute on the fly" pipeline scalability: ingest throughput vs
//! worker count, and the backpressure behaviour vs queue depth.

use crate::bench_support::Table;
use crate::config::Config;
use crate::coordinator::Pipeline;
use crate::data::{gen, DataDist};

use super::common::Acceptance;

pub fn run(fast: bool) -> Vec<Acceptance> {
    println!("E10: pipeline scaling (ingest rows/s vs workers, queue depth)");
    let (n, d, k, worker_counts): (usize, usize, usize, Vec<usize>) = if fast {
        (512, 512, 64, vec![1, 4])
    } else {
        (2048, 1024, 128, vec![1, 2, 4, 8])
    };
    let data = gen::generate(DataDist::ZipfTf { exponent: 1.1, density: 0.1 }, n, d, 0xE10);
    let mut table = Table::new(&["workers", "queue", "rows/s", "speedup"]);
    let mut acc = Vec::new();
    let mut base_rate = 0.0;
    let mut rates = Vec::new();
    for &w in &worker_counts {
        let mut cfg = Config::default();
        cfg.n = n;
        cfg.d = d;
        cfg.k = k;
        cfg.workers = w;
        cfg.block_rows = 64;
        let pipeline = Pipeline::new(cfg).unwrap();
        let report = pipeline.ingest(&data).unwrap();
        let rate = n as f64 / report.elapsed.as_secs_f64();
        if w == worker_counts[0] {
            base_rate = rate;
        }
        rates.push((w, rate));
        table.row(&[
            w.to_string(),
            "8".to_string(),
            format!("{rate:.0}"),
            format!("{:.2}x", rate / base_rate),
        ]);
    }

    // Queue-depth sweep at max workers: throughput should be roughly
    // flat once the queue covers worker count (backpressure, not
    // starvation, is the design point).
    let w = *worker_counts.last().unwrap();
    let mut depth_rates = Vec::new();
    for depth in [1usize, 2, 8, 32] {
        let mut cfg = Config::default();
        cfg.n = n;
        cfg.d = d;
        cfg.k = k;
        cfg.workers = w;
        cfg.queue_depth = depth;
        cfg.block_rows = 64;
        let pipeline = Pipeline::new(cfg).unwrap();
        let report = pipeline.ingest(&data).unwrap();
        let rate = n as f64 / report.elapsed.as_secs_f64();
        depth_rates.push((depth, rate));
        table.row(&[
            w.to_string(),
            depth.to_string(),
            format!("{rate:.0}"),
            format!("{:.2}x", rate / base_rate),
        ]);
    }
    table.print();

    let last = rates.last().unwrap();
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    if cores > 1 {
        acc.push(Acceptance::check(
            "ingest scales with workers",
            last.1 > 1.5 * base_rate || last.0 == 1,
            format!("{}w: {:.2}x over 1w ({cores} cores)", last.0, last.1 / base_rate),
        ));
    } else {
        // Single-core host (this testbed): scaling is impossible by
        // construction; require bounded oversubscription overhead
        // instead and report the substitution (DESIGN.md §3).
        acc.push(Acceptance::check(
            "single-core host: oversubscription overhead bounded",
            last.1 > 0.2 * base_rate,
            format!("{}w: {:.2}x over 1w (1 core)", last.0, last.1 / base_rate),
        ));
    }
    let deep = depth_rates.last().unwrap().1;
    let shallow = depth_rates.first().unwrap().1;
    acc.push(Acceptance::check(
        "deep queue not much faster than shallow (bounded queues suffice)",
        deep < shallow * 3.0,
        format!("depth1={shallow:.0} depth32={deep:.0} rows/s"),
    ));
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e10_fast_runs() {
        // Throughput scaling asserts are machine-dependent; just require
        // the harness to run and produce acceptances.
        let acc = run(true);
        assert_eq!(acc.len(), 2);
    }
}
