//! E11 — §1 motivation: stable random projections work for p ≤ 2 but are
//! structurally incapable of p = 4, while the paper's estimator
//! converges. The "failure" is not noise — the stable estimate converges
//! to the *wrong limit* (the l_2 distance), so no k fixes it.

use crate::baselines::stable::{geometric_mean_estimate, StableSketcher};
use crate::bench_support::Table;
use crate::core::decompose::{exact_distance, Decomposition};
use crate::core::estimator;
use crate::data::DataDist;
use crate::projection::sketcher::Sketcher;
use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};
use crate::util::stats::Welford;

use super::common::{Acceptance, Pair};

pub fn run(fast: bool) -> Vec<Acceptance> {
    println!("E11: stable projections at p∈{{1,2}} vs the p=4 wall");
    let (d, reps, k) = if fast { (48, 200, 64) } else { (128, 600, 128) };
    let pair = Pair::from_dist(DataDist::Uniform01, d, 4, 0xE11);
    let l1: f64 = pair
        .x64
        .iter()
        .zip(&pair.y64)
        .map(|(a, b)| (a - b).abs())
        .sum();
    let l2 = exact_distance(&pair.x64, &pair.y64, 2);
    let l4 = pair.exact;

    let stable_mc = |alpha: f64| {
        let mut w = Welford::new();
        for seed in 0..reps as u64 {
            let sk = StableSketcher::new(seed, k, alpha);
            let (u, v) = (sk.sketch(&pair.x), sk.sketch(&pair.y));
            w.push(geometric_mean_estimate(&u, &v));
        }
        w
    };
    let dec = Decomposition::new(4).unwrap();
    let ours_mc = || {
        let mut w = Welford::new();
        for seed in 0..reps as u64 {
            let spec = ProjectionSpec::new(seed, k, ProjectionDist::Normal, Strategy::Basic);
            let sk = Sketcher::new(spec, 4);
            let rows = sk.sketch_rows(&[&pair.x, &pair.y]);
            w.push(estimator::estimate(&dec, &rows[0], &rows[1]));
        }
        w
    };

    let s1 = stable_mc(1.0);
    let s2 = stable_mc(2.0);
    let ours = ours_mc();
    let mut table = Table::new(&["estimator", "target", "exact", "mc_mean", "rel_err"]);
    let mut acc = Vec::new();
    let rel = |mean: f64, exact: f64| (mean - exact).abs() / exact;
    table.row(&[
        "stable α=1 (CMS+GM)".into(),
        "l_1".into(),
        format!("{l1:.4}"),
        format!("{:.4}", s1.mean()),
        format!("{:.3}", rel(s1.mean(), l1)),
    ]);
    table.row(&[
        "stable α=2".into(),
        "l_2^2".into(),
        format!("{l2:.4}"),
        format!("{:.4}", s2.mean()),
        format!("{:.3}", rel(s2.mean(), l2)),
    ]);
    table.row(&[
        "stable α=2 read as p=4".into(),
        "l_4^4".into(),
        format!("{l4:.4}"),
        format!("{:.4}", s2.mean()),
        format!("{:.3}", rel(s2.mean(), l4)),
    ]);
    table.row(&[
        "this paper (basic, k)".into(),
        "l_4^4".into(),
        format!("{l4:.4}"),
        format!("{:.4}", ours.mean()),
        format!("{:.3}", rel(ours.mean(), l4)),
    ]);
    table.print();

    acc.push(Acceptance::check(
        "stable α=1 recovers l_1",
        rel(s1.mean(), l1) < 0.05,
        format!("rel={:.3}", rel(s1.mean(), l1)),
    ));
    acc.push(Acceptance::check(
        "stable α=2 recovers l_2",
        rel(s2.mean(), l2) < 0.05,
        format!("rel={:.3}", rel(s2.mean(), l2)),
    ));
    acc.push(Acceptance::check(
        "stable cannot reach l_4 (wrong limit)",
        rel(s2.mean(), l4) > 0.5,
        format!("rel={:.3}", rel(s2.mean(), l4)),
    ));
    acc.push(Acceptance::check(
        "our estimator converges to l_4",
        ours.z_against(l4).abs() < 4.5,
        format!("z={:+.2}", ours.z_against(l4)),
    ));
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e11_fast_passes() {
        let acc = run(true);
        assert!(acc.iter().all(|a| a.ok), "{acc:?}");
    }
}
