//! E1 — Lemma 1: the basic-strategy estimator d̂_(4) is unbiased and its
//! variance matches the closed form (including the Δ₄ cross-term).
//!
//! Sweep: data regime × k; acceptance: |bias z| < 4.5 and empirical/
//! theory variance ratio within MC tolerance.

use crate::bench_support::Table;
use crate::projection::{ProjectionDist, Strategy};

use super::common::{self, Acceptance, Estimator, Pair};

pub struct Params {
    pub d: usize,
    pub ks: Vec<usize>,
    pub reps: usize,
}

impl Params {
    pub fn new(fast: bool) -> Self {
        if fast {
            Params { d: 64, ks: vec![16, 64], reps: 800 }
        } else {
            Params { d: 256, ks: vec![16, 32, 64, 128, 256, 512], reps: 2000 }
        }
    }
}

/// Run the sweep for one strategy (shared by E1/E2).
pub fn sweep(strategy: Strategy, params: &Params) -> (Table, Vec<Acceptance>) {
    let mut table = Table::new(&[
        "dist", "k", "exact", "mc_mean", "bias_z", "mc_var", "theory_var", "ratio",
    ]);
    let mut acc = Vec::new();
    let tol = common::var_tolerance(params.reps);
    for (name, dist) in common::data_regimes() {
        let pair = Pair::from_dist(dist, params.d, 4, 0xE1);
        for &k in &params.ks {
            let tv = common::theory_var(&pair, strategy, ProjectionDist::Normal, k);
            let r = common::run_mc(
                &pair,
                strategy,
                ProjectionDist::Normal,
                k,
                params.reps,
                Estimator::Plain,
                tv,
            );
            table.row(&[
                name.to_string(),
                k.to_string(),
                format!("{:.4e}", r.exact),
                format!("{:.4e}", r.mc_mean),
                format!("{:+.2}", r.bias_z),
                format!("{:.4e}", r.mc_var),
                format!("{:.4e}", r.theory_var),
                format!("{:.3}", r.var_ratio()),
            ]);
            acc.push(Acceptance::check(
                format!("{name}/k={k} unbiased"),
                r.bias_z.abs() < 4.5,
                format!("z={:+.2}", r.bias_z),
            ));
            acc.push(Acceptance::check(
                format!("{name}/k={k} variance"),
                (r.var_ratio() - 1.0).abs() < tol,
                format!("ratio={:.3} tol={tol:.3}", r.var_ratio()),
            ));
        }
    }
    (table, acc)
}

pub fn run(fast: bool) -> Vec<Acceptance> {
    let params = Params::new(fast);
    println!("E1: Lemma 1 — basic strategy, p=4, normal projections");
    let (table, acc) = sweep(Strategy::Basic, &params);
    table.print();
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e1_fast_passes() {
        let acc = run(true);
        assert!(acc.iter().all(|a| a.ok), "{acc:?}");
    }
}
