//! E2 — Lemma 2: the alternative strategy (independent R per order) is
//! unbiased with the cross-term-free variance; plus the storage cost the
//! alternative strategy pays (two sketch sides per row).

use crate::projection::sketcher::Sketcher;
use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};

use super::common::Acceptance;
use super::e1_lemma1::{sweep, Params};

pub fn run(fast: bool) -> Vec<Acceptance> {
    let params = Params::new(fast);
    println!("E2: Lemma 2 — alternative strategy, p=4, normal projections");
    let (table, mut acc) = sweep(Strategy::Alternative, &params);
    table.print();

    // Storage overhead: alternative rows store both sketch sides.
    let k = 64;
    let row: Vec<f32> = (0..128).map(|i| (i as f32 * 0.1).sin()).collect();
    let basic = Sketcher::new(
        ProjectionSpec::new(1, k, ProjectionDist::Normal, Strategy::Basic),
        4,
    )
    .sketch_row(&row);
    let alt = Sketcher::new(
        ProjectionSpec::new(1, k, ProjectionDist::Normal, Strategy::Alternative),
        4,
    )
    .sketch_row(&row);
    let ratio = alt.sketch_bytes() as f64 / basic.sketch_bytes() as f64;
    println!("  storage: alt/basic bytes = {ratio:.2} (moments shared)");
    acc.push(Acceptance::check(
        "alt pays ~2x sketch storage",
        (1.5..=2.0).contains(&ratio),
        format!("ratio={ratio:.2}"),
    ));
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e2_fast_passes() {
        let acc = run(true);
        assert!(acc.iter().all(|a| a.ok), "{acc:?}");
    }
}
