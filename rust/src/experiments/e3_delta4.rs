//! E3 — Lemma 3: Δ₄ = Var(basic) − Var(alt) is ≤ 0 whenever the data are
//! non-negative (the basic strategy wins), and can flip sign on signed
//! data (the paper's x ≤ 0 ≤ y example).
//!
//! Checks:
//! 1. Δ₄ ≤ 0 on 100% of non-negative draws (formula evaluation).
//! 2. Δ₄ ≥ 0 on the adversarial all-negative-x / all-positive-y regime.
//! 3. The *measured* variance gap between strategies matches Δ₄ (MC).

use crate::bench_support::Table;
use crate::core::variance;
use crate::data::{gen, DataDist};
use crate::projection::{ProjectionDist, Strategy};

use super::common::{self, Acceptance, Estimator, Pair};

pub fn run(fast: bool) -> Vec<Acceptance> {
    println!("E3: Lemma 3 — sign of Δ₄ by data regime");
    let (draws, d, reps) = if fast { (40, 64, 1500) } else { (200, 256, 4000) };
    let mut acc = Vec::new();
    let mut table = Table::new(&["regime", "draws", "delta4<=0", "min", "max"]);

    // 1. Non-negative regimes: Δ₄ ≤ 0 always.
    for (name, dist) in common::data_regimes() {
        if !dist.non_negative() {
            continue;
        }
        let mut le_zero = 0usize;
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for draw in 0..draws {
            let pair = Pair::from_dist(dist, d, 4, 0xE3_00 + draw as u64);
            let delta = variance::delta4(&pair.table, 64);
            le_zero += (delta <= 1e-12 * pair.exact.powi(2)) as usize;
            lo = lo.min(delta);
            hi = hi.max(delta);
        }
        table.row(&[
            name.to_string(),
            draws.to_string(),
            format!("{le_zero}/{draws}"),
            format!("{lo:.3e}"),
            format!("{hi:.3e}"),
        ]);
        acc.push(Acceptance::check(
            format!("{name}: Δ₄ ≤ 0 on all draws"),
            le_zero == draws,
            format!("{le_zero}/{draws}"),
        ));
    }

    // 2. Adversarial signed regime: x < 0 < y ⇒ Δ₄ ≥ 0 (paper §2.2).
    let mut ge_zero = 0usize;
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for draw in 0..draws {
        let m = gen::generate(DataDist::Uniform01, 2, d, 0xE3_F0 + draw as u64);
        let x: Vec<f32> = m.row(0).iter().map(|&v| -v - 0.01).collect();
        let y: Vec<f32> = m.row(1).iter().map(|&v| v + 0.01).collect();
        let pair = Pair::new(x, y, 4);
        let delta = variance::delta4(&pair.table, 64);
        ge_zero += (delta >= 0.0) as usize;
        lo = lo.min(delta);
        hi = hi.max(delta);
    }
    table.row(&[
        "neg-x/pos-y".to_string(),
        draws.to_string(),
        format!("(Δ₄≥0: {ge_zero}/{draws})"),
        format!("{lo:.3e}"),
        format!("{hi:.3e}"),
    ]);
    table.print();
    acc.push(Acceptance::check(
        "adversarial: Δ₄ ≥ 0 (alt wins)",
        ge_zero == draws,
        format!("{ge_zero}/{draws}"),
    ));

    // 3. MC: measured Var(basic) − Var(alt) ≈ Δ₄.
    let pair = Pair::from_dist(DataDist::Uniform01, d, 4, 0xE3_AA);
    let k = 32;
    let tv_b = common::theory_var(&pair, Strategy::Basic, ProjectionDist::Normal, k);
    let tv_a = common::theory_var(&pair, Strategy::Alternative, ProjectionDist::Normal, k);
    let rb = common::run_mc(
        &pair, Strategy::Basic, ProjectionDist::Normal, k, reps, Estimator::Plain, tv_b,
    );
    let ra = common::run_mc(
        &pair, Strategy::Alternative, ProjectionDist::Normal, k, reps, Estimator::Plain, tv_a,
    );
    let measured_gap = rb.mc_var - ra.mc_var;
    let delta = variance::delta4(&pair.table, k);
    println!(
        "  MC gap Var(basic)−Var(alt) = {measured_gap:.4e}, Δ₄ = {delta:.4e} \
         (basic var {:.4e}, alt var {:.4e})",
        rb.mc_var, ra.mc_var
    );
    // The gap is a difference of two noisy variances — accept within the
    // combined MC noise of the two estimates.
    let noise = common::var_tolerance(reps) * (tv_b + tv_a);
    acc.push(Acceptance::check(
        "MC variance gap matches Δ₄",
        (measured_gap - delta).abs() < noise,
        format!("gap={measured_gap:.3e} Δ₄={delta:.3e} noise={noise:.3e}"),
    ));
    acc.push(Acceptance::check(
        "basic beats alt on non-negative data (MC)",
        rb.mc_var <= ra.mc_var * (1.0 + common::var_tolerance(reps)),
        format!("basic={:.3e} alt={:.3e}", rb.mc_var, ra.mc_var),
    ));
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e3_fast_passes() {
        let acc = run(true);
        assert!(acc.iter().all(|a| a.ok), "{acc:?}");
    }
}
