//! E4 — Lemma 4: the margin-aware MLE improves on the plain estimator
//! and its variance approaches the asymptotic closed form as k grows.
//!
//! Sweep: k, on correlated and uncorrelated pairs (the MLE's gain is
//! largest when the margins carry real information about the inner
//! products). Acceptance: MLE variance ≤ plain variance (within MC
//! noise) and → Lemma 4 prediction at large k.

use crate::bench_support::Table;
use crate::core::mle::Solve;
use crate::core::variance;
use crate::data::DataDist;
use crate::projection::{ProjectionDist, Strategy};

use super::common::{self, Acceptance, Estimator, Pair};

/// A correlated pair: y = x + small noise (margins very informative).
fn correlated_pair(d: usize, p: usize, seed: u64) -> Pair {
    let base = Pair::from_dist(DataDist::Uniform01, d, p, seed);
    let y: Vec<f32> = base
        .x
        .iter()
        .zip(&base.y)
        .map(|(&x, &n)| x + 0.1 * n)
        .collect();
    Pair::new(base.x.clone(), y, p)
}

pub fn run(fast: bool) -> Vec<Acceptance> {
    println!("E4: Lemma 4 — margin MLE (alternative strategy)");
    let (d, reps, ks): (usize, usize, Vec<usize>) = if fast {
        (64, 1200, vec![16, 64])
    } else {
        (256, 3000, vec![16, 32, 64, 128, 256])
    };
    let mut acc = Vec::new();
    let mut table = Table::new(&[
        "pair", "k", "plain_var", "mle_var(mc)", "lemma4_var", "mle/plain", "mc/lemma4",
    ]);

    for (name, pair) in [
        ("uncorrelated", Pair::from_dist(DataDist::Uniform01, d, 4, 0xE4)),
        ("correlated", correlated_pair(d, 4, 0xE4)),
    ] {
        for &k in &ks {
            let plain_tv =
                common::theory_var(&pair, Strategy::Alternative, ProjectionDist::Normal, k);
            let lemma4 = variance::lemma4_mle_var(&pair.table, k);
            let r = common::run_mc(
                &pair,
                Strategy::Alternative,
                ProjectionDist::Normal,
                k,
                reps,
                Estimator::Mle(Solve::ClosedForm),
                lemma4,
            );
            let mle_plain = r.mc_var / plain_tv;
            table.row(&[
                name.to_string(),
                k.to_string(),
                format!("{plain_tv:.4e}"),
                format!("{:.4e}", r.mc_var),
                format!("{lemma4:.4e}"),
                format!("{mle_plain:.3}"),
                format!("{:.3}", r.var_ratio()),
            ]);
            acc.push(Acceptance::check(
                format!("{name}/k={k}: MLE no worse than plain"),
                mle_plain < 1.0 + common::var_tolerance(reps),
                format!("mle/plain={mle_plain:.3}"),
            ));
            // Asymptotic agreement only claimed for the largest k.
            if k == *ks.last().unwrap() {
                acc.push(Acceptance::check(
                    format!("{name}/k={k}: MC → Lemma 4"),
                    (r.var_ratio() - 1.0).abs() < 2.0 * common::var_tolerance(reps),
                    format!("ratio={:.3}", r.var_ratio()),
                ));
            }
        }
    }
    table.print();

    // One-step Newton vs closed form. The one-step estimator is only
    // asymptotically equivalent — it starts from the plain estimate, so
    // in extreme-gain regimes (correlated pairs, where the full MLE wins
    // 100×+) one step cannot close the whole gap at practical k. The
    // paper's "common practice" claim is about the moderate-gain regime:
    // compare there (uncorrelated pair).
    let pair = Pair::from_dist(DataDist::Uniform01, d, 4, 0xE4_01);
    let k = *ks.last().unwrap();
    let newton = common::run_mc(
        &pair,
        Strategy::Alternative,
        ProjectionDist::Normal,
        k,
        reps,
        Estimator::Mle(Solve::OneStepNewton),
        variance::lemma4_mle_var(&pair.table, k),
    );
    let closed = common::run_mc(
        &pair,
        Strategy::Alternative,
        ProjectionDist::Normal,
        k,
        reps,
        Estimator::Mle(Solve::ClosedForm),
        variance::lemma4_mle_var(&pair.table, k),
    );
    println!(
        "  one-step Newton vs closed form at k={k}: var {:.4e} vs {:.4e}",
        newton.mc_var, closed.mc_var
    );
    acc.push(Acceptance::check(
        "one-step Newton ≈ closed form",
        (newton.mc_var / closed.mc_var - 1.0).abs() < 2.0 * common::var_tolerance(reps),
        format!("ratio={:.3}", newton.mc_var / closed.mc_var),
    ));
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e4_fast_passes() {
        let acc = run(true);
        assert!(acc.iter().all(|a| a.ok), "{acc:?}");
    }
}
