//! E5 — §3 / Lemma 5: the p = 6 estimator is unbiased, its variance
//! matches the closed form (incl. Δ₆), and the paper's unproved
//! conjecture Δ₆ ≤ 0 on non-negative data holds empirically.

use crate::bench_support::Table;
use crate::core::variance;
use crate::projection::{ProjectionDist, Strategy};

use super::common::{self, Acceptance, Estimator, Pair};

pub fn run(fast: bool) -> Vec<Acceptance> {
    println!("E5: Lemma 5 — p=6, basic strategy");
    let (d, reps, ks, draws): (usize, usize, Vec<usize>, usize) = if fast {
        (48, 1200, vec![32], 40)
    } else {
        (128, 3000, vec![32, 64, 128, 256], 200)
    };
    let mut acc = Vec::new();
    let tol = common::var_tolerance(reps);
    let mut table = Table::new(&["dist", "k", "bias_z", "mc_var", "lemma5_var", "ratio"]);
    for (name, dist) in common::data_regimes() {
        // p=6 moments are extreme; keep to the bounded regimes for MC
        // stability (lognormal x^10 spans ~15 decades in f64).
        if name == "lognormal" {
            continue;
        }
        let pair = Pair::from_dist(dist, d, 6, 0xE5);
        for &k in &ks {
            let tv = common::theory_var(&pair, Strategy::Basic, ProjectionDist::Normal, k);
            let r = common::run_mc(
                &pair,
                Strategy::Basic,
                ProjectionDist::Normal,
                k,
                reps,
                Estimator::Plain,
                tv,
            );
            table.row(&[
                name.to_string(),
                k.to_string(),
                format!("{:+.2}", r.bias_z),
                format!("{:.4e}", r.mc_var),
                format!("{:.4e}", r.theory_var),
                format!("{:.3}", r.var_ratio()),
            ]);
            acc.push(Acceptance::check(
                format!("{name}/k={k} unbiased (p=6)"),
                r.bias_z.abs() < 4.5,
                format!("z={:+.2}", r.bias_z),
            ));
            acc.push(Acceptance::check(
                format!("{name}/k={k} Lemma 5 variance"),
                (r.var_ratio() - 1.0).abs() < tol,
                format!("ratio={:.3}", r.var_ratio()),
            ));
        }
    }
    table.print();

    // Δ₆ ≤ 0 conjecture on non-negative draws (the paper states it
    // without proof; we verify it numerically across regimes).
    let mut le_zero = 0usize;
    let mut total = 0usize;
    for (_, dist) in common::data_regimes().into_iter().filter(|(_, d)| d.non_negative()) {
        for draw in 0..draws {
            let pair = Pair::from_dist(dist, d, 6, 0xE5_70 + draw as u64);
            let delta = variance::delta6(&pair.table, 64);
            le_zero += (delta <= 1e-10 * pair.exact.powi(2)) as usize;
            total += 1;
        }
    }
    println!("  Δ₆ ≤ 0 on {le_zero}/{total} non-negative draws");
    acc.push(Acceptance::check(
        "Δ₆ ≤ 0 conjecture (non-negative)",
        le_zero == total,
        format!("{le_zero}/{total}"),
    ));
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e5_fast_passes() {
        let acc = run(true);
        assert!(acc.iter().all(|a| a.ok), "{acc:?}");
    }
}
