//! E6 — §4 / Lemma 6: sub-Gaussian projections. The variance is a
//! function of the projection kurtosis s alone; the sparse three-point
//! family trades a (s−3)-term variance change for 1−1/s sparsity (and a
//! proportional sketching speedup).

use std::time::Instant;

use crate::bench_support::Table;
use crate::data::DataDist;
use crate::projection::sketcher::Sketcher;
use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};

use super::common::{self, Acceptance, Estimator, Pair};

pub fn run(fast: bool) -> Vec<Acceptance> {
    println!("E6: Lemma 6 — sub-Gaussian projections, p=4, basic strategy");
    let (d, reps, k) = if fast { (64, 1200, 32) } else { (256, 3000, 64) };
    let dists: Vec<(&str, ProjectionDist)> = vec![
        ("normal (s=3)", ProjectionDist::Normal),
        ("uniform (s=9/5)", ProjectionDist::Uniform),
        ("3pt s=1", ProjectionDist::ThreePoint(1.0)),
        ("3pt s=3", ProjectionDist::ThreePoint(3.0)),
        ("3pt s=10", ProjectionDist::ThreePoint(10.0)),
        ("3pt s=100", ProjectionDist::ThreePoint(100.0)),
    ];
    let mut acc = Vec::new();
    let tol = common::var_tolerance(reps);
    let pair = Pair::from_dist(DataDist::ZipfTf { exponent: 1.1, density: 0.1 }, d, 4, 0xE6);
    let mut table = Table::new(&["projection", "s", "bias_z", "mc_var", "lemma6_var", "ratio"]);
    for (name, dist) in &dists {
        let s = dist.kurtosis();
        let tv = common::theory_var(&pair, Strategy::Basic, *dist, k);
        let r = common::run_mc(&pair, Strategy::Basic, *dist, k, reps, Estimator::Plain, tv);
        table.row(&[
            name.to_string(),
            format!("{s:.1}"),
            format!("{:+.2}", r.bias_z),
            format!("{:.4e}", r.mc_var),
            format!("{tv:.4e}"),
            format!("{:.3}", r.var_ratio()),
        ]);
        acc.push(Acceptance::check(
            format!("{name}: unbiased"),
            r.bias_z.abs() < 4.5,
            format!("z={:+.2}", r.bias_z),
        ));
        acc.push(Acceptance::check(
            format!("{name}: Lemma 6 variance"),
            (r.var_ratio() - 1.0).abs() < tol,
            format!("ratio={:.3}", r.var_ratio()),
        ));
    }
    table.print();

    // Sparsity speedup: dense vs s=100 three-point sketching wall-clock.
    // R materialization (counter-hash per entry) is shared across the
    // batch, so the sparse win shows at realistic batch sizes.
    let rows = 256;
    let data = crate::data::gen::generate(DataDist::Uniform01, rows, 1024, 0xE6_01);
    let refs: Vec<&[f32]> = (0..rows).map(|i| data.row(i)).collect();
    let time = |dist: ProjectionDist| {
        let sk = Sketcher::new(ProjectionSpec::new(7, 64, dist, Strategy::Basic), 4);
        let t = Instant::now();
        let out = sk.sketch_rows(&refs);
        std::hint::black_box(&out);
        t.elapsed().as_secs_f64()
    };
    let t_dense = time(ProjectionDist::Normal);
    let t_sparse = time(ProjectionDist::ThreePoint(100.0));
    let speedup = t_dense / t_sparse;
    println!("  sketch speedup 3pt(s=100) vs normal: {speedup:.1}x (1−1/s = 0.99 sparsity)");
    acc.push(Acceptance::check(
        "sparse three-point sketches faster",
        speedup > 1.2,
        format!("{speedup:.1}x"),
    ));
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e6_fast_passes() {
        let acc = run(true);
        assert!(acc.iter().all(|a| a.ok), "{acc:?}");
    }
}
