//! E7 — §5 headline: sketches cut all-pairs compute from O(n²D) to
//! O(n²k) (plus an O(nD) scan) and storage from O(nD) to O(nk).
//!
//! Sweep D at fixed n, k; measure exact all-pairs wall-clock vs
//! (ingest + sketch all-pairs), and the storage ratio. Acceptance: the
//! sketch path's *pairwise phase* is ~D/k faster at large D (shape, not
//! absolute), the crossover lands where D ≳ k, and storage compresses
//! by ~D/k.

use std::time::Instant;

use crate::baselines::exact;
use crate::bench_support::Table;
use crate::config::Config;
use crate::coordinator::Pipeline;
use crate::data::{gen, DataDist};

use super::common::Acceptance;

pub struct RowResult {
    pub d: usize,
    pub exact_s: f64,
    /// Ingest wall-clock on the GEMM block path (the default).
    pub ingest_s: f64,
    /// Ingest wall-clock on the per-row reference path.
    pub ingest_per_row_s: f64,
    /// All-pairs wall-clock on the blocked arena path.
    pub pairs_s: f64,
    /// All-pairs wall-clock on the per-row reference path.
    pub pairs_per_row_s: f64,
    /// Max |arena − per-row| over all pairs (must be fp-noise).
    pub arena_abs_diff: f64,
    pub storage_ratio: f64,
    pub pair_speedup: f64,
}

pub fn sweep(n: usize, k: usize, ds: &[usize], workers: usize) -> Vec<RowResult> {
    let mut out = Vec::new();
    for &d in ds {
        let data = gen::generate(DataDist::ZipfTf { exponent: 1.1, density: 0.1 }, n, d, 0xE7);
        let t0 = Instant::now();
        let exact_dists = exact::pairwise_condensed(&data, 4, workers);
        let exact_s = t0.elapsed().as_secs_f64();
        std::hint::black_box(&exact_dists);

        let mut cfg = Config::default();
        cfg.k = k;
        cfg.d = d;
        cfg.n = n;
        cfg.workers = workers;
        let pipeline = Pipeline::new(cfg.clone()).unwrap();
        let t1 = Instant::now();
        let report = pipeline.ingest(&data).unwrap();
        let ingest_s = t1.elapsed().as_secs_f64();
        // Per-row reference ingest (old path) on an identical pipeline —
        // the GEMM-vs-baseline ingest column.
        let ingest_per_row_s = {
            let mut cfg_pr = cfg.clone();
            cfg_pr.ingest_gemm = false;
            let per_row = Pipeline::new(cfg_pr).unwrap();
            let t = Instant::now();
            per_row.ingest(&data).unwrap();
            t.elapsed().as_secs_f64()
        };
        let t2 = Instant::now();
        let est = pipeline.all_pairs_condensed();
        let pairs_s = t2.elapsed().as_secs_f64();
        std::hint::black_box(&est);
        let t3 = Instant::now();
        let est_per_row = pipeline.all_pairs_condensed_per_row();
        let pairs_per_row_s = t3.elapsed().as_secs_f64();
        let arena_abs_diff = est
            .iter()
            .zip(&est_per_row)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        std::hint::black_box(&est_per_row);

        out.push(RowResult {
            d,
            exact_s,
            ingest_s,
            ingest_per_row_s,
            pairs_s,
            pairs_per_row_s,
            arena_abs_diff,
            storage_ratio: report.data_bytes as f64 / report.sketch_bytes as f64,
            pair_speedup: exact_s / pairs_s,
        });
    }
    out
}

pub fn run(fast: bool) -> Vec<Acceptance> {
    println!("E7: cost crossover — O(n²D) exact vs O(nD) scan + O(n²k) estimates");
    let (n, k, ds, workers): (usize, usize, Vec<usize>, usize) = if fast {
        (128, 64, vec![256, 1024, 4096], 4)
    } else {
        (512, 128, vec![256, 512, 1024, 2048, 4096, 8192, 16384], 4)
    };
    let rows = sweep(n, k, &ds, workers);
    let mut table = Table::new(&[
        "D",
        "exact_s",
        "ingest_s",
        "ingest_pr_s",
        "ingest_gain",
        "est_pairs_s",
        "per_row_s",
        "arena_gain",
        "pair_speedup",
        "D/k",
        "storage_ratio",
    ]);
    for r in &rows {
        table.row(&[
            r.d.to_string(),
            format!("{:.3}", r.exact_s),
            format!("{:.3}", r.ingest_s),
            format!("{:.3}", r.ingest_per_row_s),
            format!("{:.1}x", r.ingest_per_row_s / r.ingest_s.max(1e-12)),
            format!("{:.3}", r.pairs_s),
            format!("{:.3}", r.pairs_per_row_s),
            format!("{:.1}x", r.pairs_per_row_s / r.pairs_s.max(1e-12)),
            format!("{:.1}x", r.pair_speedup),
            format!("{:.1}", r.d as f64 / k as f64),
            format!("{:.1}x", r.storage_ratio),
        ]);
    }
    table.print();

    let mut acc = Vec::new();
    let last = rows.last().unwrap();
    let first = rows.first().unwrap();
    acc.push(Acceptance::check(
        "pairwise speedup grows with D",
        last.pair_speedup > first.pair_speedup,
        format!("{:.1}x → {:.1}x", first.pair_speedup, last.pair_speedup),
    ));
    acc.push(Acceptance::check(
        "large-D pairwise speedup ≳ D/(4k)",
        last.pair_speedup > last.d as f64 / k as f64 / 4.0,
        format!("{:.1}x vs D/k={:.1}", last.pair_speedup, last.d as f64 / k as f64),
    ));
    // Storage: sketch bytes ~ orders·k floats (+ moments) vs D floats.
    acc.push(Acceptance::check(
        "storage compresses at large D",
        last.storage_ratio > last.d as f64 / (4.0 * 3.0 * k as f64),
        format!("{:.1}x at D={}", last.storage_ratio, last.d),
    ));
    // End-to-end (scan included) still wins at the largest D.
    acc.push(Acceptance::check(
        "end-to-end sketch path wins at large D",
        last.exact_s > last.ingest_s + last.pairs_s,
        format!(
            "exact {:.3}s vs ingest+est {:.3}s",
            last.exact_s,
            last.ingest_s + last.pairs_s
        ),
    ));
    // Arena kernel: identical results (fp noise at most) and not slower
    // than the per-row reference (lenient bound — timing on shared CI
    // boxes wobbles; hotpath.rs carries the strict ≥3× measurement).
    let max_diff = rows.iter().map(|r| r.arena_abs_diff).fold(0.0f64, f64::max);
    acc.push(Acceptance::check(
        "arena all-pairs matches per-row results",
        max_diff < 1e-9,
        format!("max |Δ| = {max_diff:.3e}"),
    ));
    acc.push(Acceptance::check(
        "arena all-pairs within 2x of per-row (timing, lenient)",
        last.pairs_per_row_s / last.pairs_s.max(1e-12) > 0.5,
        format!(
            "arena {:.3}s vs per-row {:.3}s",
            last.pairs_s, last.pairs_per_row_s
        ),
    ));
    // GEMM ingest vs per-row reference ingest (timing-based; the strict
    // ≥2× measurement lives in benches/hotpath.rs → BENCH_ingest.json).
    acc.push(Acceptance::check(
        "gemm ingest not slower than per-row (timing, lenient)",
        last.ingest_per_row_s / last.ingest_s.max(1e-12) > 0.5,
        format!(
            "gemm {:.3}s vs per-row {:.3}s at D={}",
            last.ingest_s, last.ingest_per_row_s, last.d
        ),
    ));
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e7_fast_shape_holds() {
        let acc = run(true);
        // Timing-based checks can wobble on loaded CI machines; require
        // the structural ones (speedup growth + storage) to hold.
        let structural: Vec<_> = acc
            .iter()
            .filter(|a| {
                a.label.contains("storage")
                    || a.label.contains("grows")
                    || a.label.contains("matches")
            })
            .collect();
        assert!(structural.iter().all(|a| a.ok), "{structural:?}");
    }
}
