//! E8 — intro use-case: nearest-neighbor search under l_4 on TF vectors.
//! recall@10 vs sketch width k, with and without exact re-ranking, plus
//! the coordinate-sampling baseline at matched storage and the
//! arena-batch vs per-row query-path comparison.

use std::time::Instant;

use crate::baselines::sampling::{self, CoordSampler};
use crate::bench_support::Table;
use crate::data::corpus;
use crate::knn::{exact_knn, recall, KnnIndex};
use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};

use super::common::Acceptance;

pub fn run(fast: bool) -> Vec<Acceptance> {
    println!("E8: sketch k-NN on TF corpus (recall@10 vs k)");
    let (n, d, queries, ks): (usize, usize, usize, Vec<usize>) = if fast {
        (300, 1024, 25, vec![16, 64])
    } else {
        (2000, 1024, 100, vec![8, 16, 32, 64, 128, 256])
    };
    let data = corpus::generate(n, d, 80, 0xE8).tf;
    let m = 10;
    let p = 4;
    // Rerank pool: ~10% of the corpus (the standard two-phase budget).
    let pool = (n / 10).max(4 * m);
    let mut table = Table::new(&[
        "k", "recall@10", "recall(mle)", "recall(mle)+rerank", "coord-sample",
    ]);
    let mut acc = Vec::new();
    let mut recalls = Vec::new();
    let qs: Vec<Vec<f32>> = (0..queries).map(|qi| data.row((qi * 13) % n).to_vec()).collect();
    let mut last_idx: Option<KnnIndex> = None;
    for &k in &ks {
        let mut idx = KnnIndex::build(
            &data,
            ProjectionSpec::new(0xE8, k, ProjectionDist::Normal, Strategy::Basic),
            p,
        )
        .unwrap();
        let sampler = CoordSampler::new(0xE8, 3 * k); // matched floats: 3 orders × k
        // Coordinate samples are the stored "index": build once per k.
        let coord_index: Vec<_> = (0..n).map(|i| sampler.sample(data.row(i))).collect();
        let (mut r_plain, mut r_mle, mut r_rerank, mut r_coord) = (0.0, 0.0, 0.0, 0.0);
        for qi in 0..queries {
            let q = &qs[qi];
            let truth = exact_knn(&data, q, m, p);
            idx.use_mle = false;
            r_plain += recall(&idx.query(q, m), &truth);
            // Lemma 4 margin MLE: on non-negative TF rows the margins are
            // highly informative — this is the paper's own fix for the
            // plain estimator's noise (E4) applied to the use-case.
            idx.use_mle = true;
            r_mle += recall(&idx.query(q, m), &truth);
            r_rerank += recall(&idx.query_rerank(&data, q, m, pool), &truth);
            // Coordinate-sampling candidate ranking at matched storage.
            let qs = sampler.sample(q);
            let mut scored: Vec<(usize, f64)> = (0..n)
                .map(|i| (i, sampling::estimate(&qs, &coord_index[i], p)))
                .collect();
            scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            let got: Vec<crate::knn::Neighbor> = scored[..m]
                .iter()
                .map(|&(i, dist)| crate::knn::Neighbor { index: i, distance: dist, exact: false })
                .collect();
            r_coord += recall(&got, &truth);
        }
        let qn = queries as f64;
        table.row(&[
            k.to_string(),
            format!("{:.3}", r_plain / qn),
            format!("{:.3}", r_mle / qn),
            format!("{:.3}", r_rerank / qn),
            format!("{:.3}", r_coord / qn),
        ]);
        recalls.push((k, r_plain / qn, r_rerank / qn, r_coord / qn, r_mle / qn));
        idx.use_mle = false;
        last_idx = Some(idx);
    }
    table.print();

    // Arena-batch vs per-row query path at the largest k: one batched
    // arena scan over every query vs a per-query per-row scoring loop —
    // identical result sets, measurably cheaper.
    let idx = last_idx.expect("at least one k swept");
    let qrefs: Vec<&[f32]> = qs.iter().map(|v| v.as_slice()).collect();
    let t0 = Instant::now();
    let batch = idx.query_batch(&qrefs, m);
    let batch_s = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let per_row: Vec<_> = qrefs.iter().map(|q| idx.query_per_row(q, m)).collect();
    let per_row_s = t1.elapsed().as_secs_f64();
    let mut result_diff = 0usize;
    for (a, b) in batch.iter().zip(&per_row) {
        if a.len() != b.len()
            || a.iter().zip(b).any(|(x, y)| {
                x.index != y.index
                    || (x.distance - y.distance).abs() > 1e-12 * y.distance.abs().max(1.0)
            })
        {
            result_diff += 1;
        }
    }
    println!(
        "arena batch: {queries} queries in {batch_s:.3}s vs per-row loop {per_row_s:.3}s \
         ({:.1}x)",
        per_row_s / batch_s.max(1e-12)
    );

    let first = recalls.first().unwrap();
    let last = recalls.last().unwrap();
    acc.push(Acceptance::check(
        "recall grows with k",
        last.1 > first.1,
        format!("{:.3} → {:.3}", first.1, last.1),
    ));
    acc.push(Acceptance::check(
        "margin MLE ≥ plain at largest k (Lemma 4 in the use-case)",
        last.4 >= last.1,
        format!("{:.3} vs {:.3}", last.4, last.1),
    ));
    acc.push(Acceptance::check(
        "rerank ≥ plain at largest k",
        last.2 >= last.1,
        format!("{:.3} vs {:.3}", last.2, last.1),
    ));
    acc.push(Acceptance::check(
        "mle+rerank recall ≥ 0.85 at largest k (10% pool)",
        last.2 >= 0.85,
        format!("{:.3}", last.2),
    ));
    acc.push(Acceptance::check(
        "arena batch matches per-row query results",
        result_diff == 0,
        format!("{result_diff}/{queries} queries differ"),
    ));
    // The coord-sample column is informational: with a *shared* index
    // set, sampling ranks by the exact distance restricted to a random
    // subspace — competitive for ranking TF documents (head buckets are
    // shared within a topic), even though its distance *estimates* have
    // catastrophic variance on spiky data (see baselines::sampling tests
    // and E11). No acceptance is attached; the table tells the story.
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e8_fast_passes() {
        let acc = run(true);
        assert!(acc.iter().all(|a| a.ok), "{acc:?}");
    }
}
