//! E9 — §2.3 ablation: plain (no margins) vs one-step Newton vs full
//! closed-form cubic MLE, across k. Reports the variance-reduction
//! ratio and the compute cost of each estimator.

use std::time::Instant;

use crate::bench_support::Table;
use crate::core::decompose::Decomposition;
use crate::core::estimator;
use crate::core::mle::{self, Solve};
use crate::core::variance;
use crate::data::DataDist;
use crate::projection::sketcher::Sketcher;
use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};

use super::common::{self, Acceptance, Estimator, Pair};

pub fn run(fast: bool) -> Vec<Acceptance> {
    println!("E9: ablation — plain vs one-step Newton vs closed-form cubic MLE");
    let (d, reps, ks): (usize, usize, Vec<usize>) = if fast {
        (64, 1200, vec![16, 64])
    } else {
        (256, 3000, vec![16, 32, 64, 128, 256])
    };
    let pair = Pair::from_dist(DataDist::Uniform01, d, 4, 0xE9);
    let mut table = Table::new(&[
        "k", "plain_var", "newton_var", "cubic_var", "newton/plain", "cubic/plain", "lemma4/plain",
    ]);
    let mut acc = Vec::new();
    for &k in &ks {
        let plain_tv = common::theory_var(&pair, Strategy::Alternative, ProjectionDist::Normal, k);
        let lemma4 = variance::lemma4_mle_var(&pair.table, k);
        let plain = common::run_mc(
            &pair, Strategy::Alternative, ProjectionDist::Normal, k, reps,
            Estimator::Plain, plain_tv,
        );
        let newton = common::run_mc(
            &pair, Strategy::Alternative, ProjectionDist::Normal, k, reps,
            Estimator::Mle(Solve::OneStepNewton), lemma4,
        );
        let cubic = common::run_mc(
            &pair, Strategy::Alternative, ProjectionDist::Normal, k, reps,
            Estimator::Mle(Solve::ClosedForm), lemma4,
        );
        table.row(&[
            k.to_string(),
            format!("{:.4e}", plain.mc_var),
            format!("{:.4e}", newton.mc_var),
            format!("{:.4e}", cubic.mc_var),
            format!("{:.3}", newton.mc_var / plain.mc_var),
            format!("{:.3}", cubic.mc_var / plain.mc_var),
            format!("{:.3}", lemma4 / plain_tv),
        ]);
        if k == *ks.last().unwrap() {
            let tol = common::var_tolerance(reps);
            acc.push(Acceptance::check(
                "margins help (cubic < plain)",
                cubic.mc_var < plain.mc_var * (1.0 + tol),
                format!("ratio={:.3}", cubic.mc_var / plain.mc_var),
            ));
            acc.push(Acceptance::check(
                "one-step Newton captures most of the gain",
                newton.mc_var < plain.mc_var * (1.0 + tol)
                    && (newton.mc_var / cubic.mc_var - 1.0).abs() < 2.0 * tol,
                format!("newton/cubic={:.3}", newton.mc_var / cubic.mc_var),
            ));
        }
    }
    table.print();

    // Estimator compute cost (ns/estimate) — the price of the gain.
    let k = *ks.last().unwrap();
    let sk = Sketcher::new(
        ProjectionSpec::new(1, k, ProjectionDist::Normal, Strategy::Alternative),
        4,
    );
    let rows = sk.sketch_rows(&[&pair.x, &pair.y]);
    let dec = Decomposition::new(4).unwrap();
    let iters = if fast { 20_000 } else { 200_000 };
    let time = |f: &dyn Fn() -> f64| {
        let t = Instant::now();
        let mut acc = 0.0;
        for _ in 0..iters {
            acc += f();
        }
        std::hint::black_box(acc);
        t.elapsed().as_secs_f64() / iters as f64 * 1e9
    };
    let t_plain = time(&|| estimator::estimate(&dec, &rows[0], &rows[1]));
    let t_newton = time(&|| mle::estimate_mle(&dec, &rows[0], &rows[1], Solve::OneStepNewton));
    let t_cubic = time(&|| mle::estimate_mle(&dec, &rows[0], &rows[1], Solve::ClosedForm));
    println!("  cost/estimate: plain {t_plain:.0}ns, newton {t_newton:.0}ns, cubic {t_cubic:.0}ns");
    acc.push(Acceptance::check(
        "one-step Newton cheaper than closed form",
        t_newton < t_cubic,
        format!("{t_newton:.0}ns vs {t_cubic:.0}ns"),
    ));
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e9_fast_passes() {
        let acc = run(true);
        assert!(acc.iter().all(|a| a.ok), "{acc:?}");
    }
}
