//! The E1..E11 reproduction harness — one experiment per paper claim
//! (the paper is a theory report with no numbered tables/figures, so
//! each Lemma / section claim is the "table" we regenerate; DESIGN.md §4
//! maps experiment ids to claims).
//!
//! Each experiment prints the table it regenerates and returns a list
//! of [`common::Acceptance`] checks; `benches/` targets and the `lpsketch
//! exp` CLI both route through these functions.

pub mod common;
pub mod e1_lemma1;
pub mod e2_lemma2;
pub mod e3_delta4;
pub mod e4_mle;
pub mod e5_p6;
pub mod e6_subgauss;
pub mod e7_throughput;
pub mod e8_knn;
pub mod e9_ablation;
pub mod e10_pipeline;
pub mod e11_stable;

use common::Acceptance;

/// Registered experiments: (id, description, runner).
pub fn registry() -> Vec<(&'static str, &'static str, fn(bool) -> Vec<Acceptance>)> {
    vec![
        ("e1", "Lemma 1: basic-strategy variance (p=4)", e1_lemma1::run),
        ("e2", "Lemma 2: alternative-strategy variance (p=4)", e2_lemma2::run),
        ("e3", "Lemma 3: sign of Δ₄ by data regime", e3_delta4::run),
        ("e4", "Lemma 4: margin MLE", e4_mle::run),
        ("e5", "Lemma 5: p=6 estimator + Δ₆ conjecture", e5_p6::run),
        ("e6", "Lemma 6: sub-Gaussian projections", e6_subgauss::run),
        ("e7", "§5 headline: cost/storage crossover", e7_throughput::run),
        ("e8", "intro: sketch k-NN recall", e8_knn::run),
        ("e9", "§2.3 ablation: margin estimators", e9_ablation::run),
        ("e10", "pipeline scaling", e10_pipeline::run),
        ("e11", "§1: stable projections fail for p=4", e11_stable::run),
    ]
}

/// Run one experiment by id; `fast` shrinks sweeps for tests/CI.
pub fn run(id: &str, fast: bool) -> anyhow::Result<Vec<Acceptance>> {
    let reg = registry();
    let (_, _, f) = reg
        .iter()
        .find(|(eid, _, _)| *eid == id)
        .ok_or_else(|| anyhow::anyhow!("unknown experiment {id:?} (e1..e11)"))?;
    Ok(f(fast))
}

/// Run every experiment; returns (id, all-passed).
pub fn run_all(fast: bool) -> Vec<(String, bool)> {
    registry()
        .into_iter()
        .map(|(id, _, f)| {
            println!("\n=== {id} ===");
            let acc = f(fast);
            let ok = common::report(&acc);
            (id.to_string(), ok)
        })
        .collect()
}
