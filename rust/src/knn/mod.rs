//! Sketch-based k-nearest-neighbor search — the paper's introductory
//! use-case ("a straightforward application would be searching for the
//! nearest neighbors using l_p distance").
//!
//! Two-phase search, the standard sketch-index pattern:
//! 1. **Candidate generation** — rank all rows by the *estimated* l_p
//!    distance from the query's sketch (O(n·k) per query instead of
//!    O(n·D)).
//! 2. **Re-ranking (optional)** — recompute exact distances for the top
//!    `rerank` candidates with a linear scan over just those rows.
//!
//! E8 measures recall@m vs sketch width k, with and without re-ranking,
//! against exact ground truth.

use crate::core::decompose::Decomposition;
use crate::core::estimator;
use crate::core::mle::{self, Solve};
use crate::data::RowMatrix;
use crate::projection::sketcher::{RowSketch, Sketcher};
use crate::projection::ProjectionSpec;

/// A built sketch index over a fixed row set.
pub struct KnnIndex {
    dec: Decomposition,
    sketcher: Sketcher,
    rows: Vec<RowSketch>,
    /// Use the margin MLE (Lemma 4) when scoring candidates.
    pub use_mle: bool,
}

/// One scored neighbor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Neighbor {
    pub index: usize,
    /// Estimated (phase 1) or exact (after re-rank) l_p^p distance.
    pub distance: f64,
    pub exact: bool,
}

impl KnnIndex {
    /// Sketch every row of `data` (the index build = one linear scan).
    pub fn build(data: &RowMatrix, spec: ProjectionSpec, p: usize) -> anyhow::Result<Self> {
        let dec = Decomposition::new(p)?;
        let sketcher = Sketcher::new(spec, p);
        let refs: Vec<&[f32]> = (0..data.n()).map(|i| data.row(i)).collect();
        let rows = sketcher.sketch_rows(&refs);
        Ok(KnnIndex { dec, sketcher, rows, use_mle: false })
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Sketch bytes held by the index (the O(nk) storage claim).
    pub fn bytes(&self) -> usize {
        self.rows.iter().map(|r| r.sketch_bytes()).sum()
    }

    /// Phase-1 query: top `m` candidates by estimated distance.
    pub fn query(&self, q: &[f32], m: usize) -> Vec<Neighbor> {
        let qs = self.sketcher.sketch_row(q);
        let mut scored: Vec<Neighbor> = self
            .rows
            .iter()
            .enumerate()
            .map(|(i, r)| Neighbor {
                index: i,
                distance: if self.use_mle {
                    mle::estimate_mle(&self.dec, &qs, r, Solve::OneStepNewton)
                } else {
                    estimator::estimate(&self.dec, &qs, r)
                },
                exact: false,
            })
            .collect();
        top_m(&mut scored, m)
    }

    /// Two-phase query: take `rerank ≥ m` candidates by sketch, then
    /// re-rank those with exact distances over `data` (must be the same
    /// matrix the index was built from).
    pub fn query_rerank(
        &self,
        data: &RowMatrix,
        q: &[f32],
        m: usize,
        rerank: usize,
    ) -> Vec<Neighbor> {
        assert_eq!(data.n(), self.rows.len(), "index/data mismatch");
        let cands = self.query(q, rerank.max(m));
        let p = self.dec.p();
        let mut exact: Vec<Neighbor> = cands
            .into_iter()
            .map(|c| Neighbor {
                index: c.index,
                distance: crate::baselines::exact::distance_f32(q, data.row(c.index), p),
                exact: true,
            })
            .collect();
        top_m(&mut exact, m)
    }
}

/// Exact top-m by full scan (ground truth for recall).
pub fn exact_knn(data: &RowMatrix, q: &[f32], m: usize, p: usize) -> Vec<Neighbor> {
    let mut scored: Vec<Neighbor> = (0..data.n())
        .map(|i| Neighbor {
            index: i,
            distance: crate::baselines::exact::distance_f32(q, data.row(i), p),
            exact: true,
        })
        .collect();
    top_m(&mut scored, m)
}

/// recall@m of `got` against ground truth `truth` (both top-m lists).
pub fn recall(got: &[Neighbor], truth: &[Neighbor]) -> f64 {
    if truth.is_empty() {
        return 1.0;
    }
    let truth_set: std::collections::HashSet<usize> = truth.iter().map(|n| n.index).collect();
    let hit = got.iter().filter(|n| truth_set.contains(&n.index)).count();
    hit as f64 / truth.len() as f64
}

fn top_m(scored: &mut Vec<Neighbor>, m: usize) -> Vec<Neighbor> {
    let m = m.min(scored.len());
    scored.select_nth_unstable_by(m.saturating_sub(1), |a, b| {
        a.distance.partial_cmp(&b.distance).unwrap()
    });
    scored.truncate(m);
    scored.sort_by(|a, b| a.distance.partial_cmp(&b.distance).unwrap());
    scored.clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{corpus, gen, DataDist};
    use crate::projection::{ProjectionDist, Strategy};

    fn spec(k: usize) -> ProjectionSpec {
        ProjectionSpec::new(99, k, ProjectionDist::Normal, Strategy::Basic)
    }

    #[test]
    fn exact_knn_finds_self_first() {
        let data = gen::generate(DataDist::Uniform01, 30, 32, 4);
        let got = exact_knn(&data, data.row(7), 3, 4);
        assert_eq!(got[0].index, 7);
        assert_eq!(got[0].distance, 0.0);
    }

    #[test]
    fn rerank_recall_dominates_sketch_only() {
        let data = corpus::generate(200, 128, 60, 11).tf;
        let idx = KnnIndex::build(&data, spec(32), 4).unwrap();
        let mut r_sketch = 0.0;
        let mut r_rerank = 0.0;
        let queries = 20;
        for qi in 0..queries {
            let q = data.row(qi * 7 % data.n()).to_vec();
            let truth = exact_knn(&data, &q, 10, 4);
            r_sketch += recall(&idx.query(&q, 10), &truth);
            r_rerank += recall(&idx.query_rerank(&data, &q, 10, 40), &truth);
        }
        r_sketch /= queries as f64;
        r_rerank /= queries as f64;
        assert!(r_rerank >= r_sketch, "rerank {r_rerank} < sketch {r_sketch}");
        assert!(r_rerank > 0.8, "rerank recall too low: {r_rerank}");
    }

    #[test]
    fn wider_sketch_improves_recall() {
        let data = corpus::generate(150, 128, 60, 13).tf;
        let mut recalls = Vec::new();
        for k in [8usize, 128] {
            let idx = KnnIndex::build(&data, spec(k), 4).unwrap();
            let mut r = 0.0;
            let queries = 15;
            for qi in 0..queries {
                let q = data.row(qi * 5 % data.n()).to_vec();
                let truth = exact_knn(&data, &q, 10, 4);
                r += recall(&idx.query(&q, 10), &truth);
            }
            recalls.push(r / queries as f64);
        }
        assert!(
            recalls[1] > recalls[0],
            "recall should grow with k: {recalls:?}"
        );
    }

    #[test]
    fn index_bytes_scale_with_k_not_d() {
        let data = gen::generate(DataDist::Uniform01, 20, 2048, 5);
        let small = KnnIndex::build(&data, spec(16), 4).unwrap();
        let big = KnnIndex::build(&data, spec(64), 4).unwrap();
        assert!(big.bytes() > 3 * small.bytes());
        assert!(big.bytes() < data.bytes(), "sketches must compress vs O(nD)");
    }

    #[test]
    fn recall_of_identical_lists_is_one() {
        let data = gen::generate(DataDist::Uniform01, 10, 16, 6);
        let truth = exact_knn(&data, data.row(0), 5, 4);
        assert_eq!(recall(&truth, &truth), 1.0);
    }
}
