//! Sketch-based k-nearest-neighbor search — the paper's introductory
//! use-case ("a straightforward application would be searching for the
//! nearest neighbors using l_p distance").
//!
//! Two-phase search, the standard sketch-index pattern:
//! 1. **Candidate generation** — rank all rows by the *estimated* l_p
//!    distance from the query's sketch (O(n·k) per query instead of
//!    O(n·D)).
//! 2. **Re-ranking (optional)** — recompute exact distances for the top
//!    `rerank` candidates with a linear scan over just those rows.
//!
//! ## Index backings and the blocked query path
//!
//! An index is backed one of two ways:
//! * **Owned** ([`KnnIndex::build`]) — sketches computed from raw data:
//!   per-row [`RowSketch`]es (the margin-MLE scoring mode consumes
//!   per-order norms the arena does not store) plus a columnar
//!   [`SketchArena`] the blocked kernels run on.
//! * **Shared** ([`KnnIndex::from_snapshot`]) — the serving-side
//!   rebuild. The index holds the snapshot's own `Arc` panels (segment
//!   blocks + zone summaries, map rows by `Arc` handle) instead of
//!   copying every sketch into a private arena: per-segment shards are
//!   keyed by block identity, so an epoch refresh re-indexes **only
//!   segments newer than the cached epoch**
//!   ([`KnnIndex::from_snapshot_incremental`]) — the per-segment work
//!   is one packed gather of marginal p-norms. By-id queries serve
//!   straight from the shared panels ([`KnnIndex::query_pos`]): the
//!   stored row IS the query payload, zero materialization.
//!
//! Queries on either backing run through
//! [`estimator::top_k_scan_zoned`]: target rows stream in cache-sized
//! tiles through a bounded per-query heap, and zoned segments are
//! visited in ascending lower-bound order and skipped when they cannot
//! beat the heap threshold. Scores are bitwise-identical to the per-row
//! reference path ([`KnnIndex::query_per_row`]).
//!
//! NaN scores (malformed input rows) are filtered, never returned; an
//! empty index returns empty neighbor lists rather than panicking.
//!
//! E8 measures recall@m vs sketch width k, with and without re-ranking,
//! against exact ground truth, plus the arena-vs-per-row batch timing.

// Serving path: clippy backs the pallas-lint serving-no-panic rule.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::sync::Arc;

use crate::coordinator::StoreSnapshot;
use crate::core::arena::SketchArena;
use crate::core::decompose::Decomposition;
use crate::core::estimator::{self, PruneStats, SketchPanels, ZoneExtent};
use crate::core::mle::{self, Solve};
use crate::core::quant::RowView;
use crate::core::zone::ZoneMeta;
use crate::data::RowMatrix;
use crate::projection::sketcher::{ColumnarBlock, RowSketch, Sketcher};
use crate::projection::ProjectionSpec;

/// One per-segment index shard served straight from snapshot-held
/// panels. `norms` is the only payload built at index time: the
/// segment's marginal p-norms gathered from the row-major moment table
/// into one packed, scan-friendly vector — the work an incremental
/// refresh skips for unchanged segments.
#[derive(Clone)]
struct SegShard {
    off: usize,
    base: u64,
    block: Arc<ColumnarBlock>,
    zone: Arc<ZoneMeta>,
    norms: Arc<Vec<f64>>,
}

/// One run of index rows: a stretch of map rows (shared by `Arc`
/// handle) or a columnar segment.
enum Shard {
    Map { off: usize, rows: Vec<Arc<RowSketch>> },
    Seg(SegShard),
}

impl Shard {
    #[inline]
    fn off(&self) -> usize {
        match self {
            Shard::Map { off, .. } => *off,
            Shard::Seg(s) => s.off,
        }
    }
}

/// Snapshot-shared [`SketchPanels`]: index row `i` is the `i`-th row of
/// the snapshot in ascending id order, served from the shard that holds
/// it — no copies of sketch panels anywhere.
struct SharedPanels {
    p: usize,
    k: usize,
    n: usize,
    /// Runs in view order; offsets ascending, tiling `[0, n)`.
    shards: Vec<Shard>,
}

impl SharedPanels {
    /// The shard holding view row `i`, plus the row's offset in it.
    #[inline]
    fn shard_for(&self, i: usize) -> (&Shard, usize) {
        debug_assert!(i < self.n);
        let pos = self.shards.partition_point(|s| s.off() <= i);
        let s = &self.shards[pos - 1];
        (s, i - s.off())
    }

    /// Zone extents for the pruned scan: segments carry their zone, map
    /// runs are never skipped.
    fn extents(&self) -> Vec<ZoneExtent<'_>> {
        self.shards
            .iter()
            .map(|s| match s {
                Shard::Map { off, rows } => {
                    ZoneExtent { off: *off, rows: rows.len(), zone: None }
                }
                Shard::Seg(seg) => ZoneExtent {
                    off: seg.off,
                    rows: seg.block.rows(),
                    zone: Some(seg.zone.as_ref()),
                },
            })
            .collect()
    }
}

impl SketchPanels for SharedPanels {
    fn n(&self) -> usize {
        self.n
    }

    fn k(&self) -> usize {
        self.k
    }

    fn p(&self) -> usize {
        self.p
    }

    fn u_row(&self, m: usize, i: usize) -> RowView<'_> {
        match self.shard_for(i) {
            (Shard::Map { rows, .. }, r) => RowView::F32(rows[r].uside.u(m)),
            (Shard::Seg(s), r) => s.block.u_view(m, r),
        }
    }

    fn v_row(&self, m: usize, i: usize) -> RowView<'_> {
        match self.shard_for(i) {
            (Shard::Map { rows, .. }, r) => RowView::F32(rows[r].vside().u(m)),
            (Shard::Seg(s), r) => s.block.v_view(m, r),
        }
    }

    fn norm_p(&self, i: usize) -> f64 {
        match self.shard_for(i) {
            (Shard::Map { rows, .. }, r) => rows[r].moments.get(self.p),
            (Shard::Seg(s), r) => s.norms[r],
        }
    }
}

/// Single-row [`SketchPanels`] view over row `row` of `inner` — the
/// by-position query payload: the stored row's panels ARE the query,
/// with no materialization and no arena copy.
struct OneRow<'a, P: SketchPanels + ?Sized> {
    inner: &'a P,
    row: usize,
}

impl<P: SketchPanels + ?Sized> SketchPanels for OneRow<'_, P> {
    fn n(&self) -> usize {
        1
    }

    fn k(&self) -> usize {
        self.inner.k()
    }

    fn p(&self) -> usize {
        self.inner.p()
    }

    fn u_row(&self, m: usize, i: usize) -> RowView<'_> {
        debug_assert_eq!(i, 0);
        self.inner.u_row(m, self.row)
    }

    fn v_row(&self, m: usize, i: usize) -> RowView<'_> {
        debug_assert_eq!(i, 0);
        self.inner.v_row(m, self.row)
    }

    fn norm_p(&self, i: usize) -> f64 {
        debug_assert_eq!(i, 0);
        self.inner.norm_p(self.row)
    }
}

/// How an index stores its rows.
enum Backing {
    /// Built from raw data: owned sketches, twice (per-row + arena).
    Owned { rows: Vec<RowSketch>, arena: SketchArena },
    /// Served from snapshot-held `Arc` panels — single-residency.
    Shared(SharedPanels),
}

/// A built sketch index over a fixed row set.
pub struct KnnIndex {
    dec: Decomposition,
    sketcher: Sketcher,
    backing: Backing,
    /// Use the margin MLE (Lemma 4) when scoring candidates (per-row
    /// scoring path; the arena kernels serve the plain estimator).
    pub use_mle: bool,
    /// Threads used to shard batched queries (defaults to the machine's
    /// available parallelism).
    pub workers: usize,
}

/// One scored neighbor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Neighbor {
    pub index: usize,
    /// Estimated (phase 1) or exact (after re-rank) l_p^p distance.
    pub distance: f64,
    pub exact: bool,
}

impl KnnIndex {
    /// Sketch every row of `data` (the index build = one linear scan)
    /// and transpose the sketches into the columnar arena.
    pub fn build(data: &RowMatrix, spec: ProjectionSpec, p: usize) -> anyhow::Result<Self> {
        let dec = Decomposition::new(p)?;
        let k = spec.k;
        let sketcher = Sketcher::new(spec, p);
        let refs: Vec<&[f32]> = (0..data.n()).map(|i| data.row(i)).collect();
        let rows = sketcher.sketch_rows(&refs);
        let arena = SketchArena::from_rows(p, k, &rows);
        let workers = std::thread::available_parallelism().map_or(1, |n| n.get());
        Ok(KnnIndex {
            dec,
            sketcher,
            backing: Backing::Owned { rows, arena },
            use_mle: false,
            workers,
        })
    }

    /// Rebuild an index from a store snapshot — the serving-side
    /// rebuild: the index serves the O(nk) sketch state of one
    /// consistent epoch cut *by `Arc` handle* (no panel copies), while
    /// ingest keeps writing to the live store underneath. Returns the
    /// index plus the store id of every index row
    /// (`Neighbor::index` i ↔ `ids[i]`).
    ///
    /// `spec` must be the projection the store's sketches were built
    /// with (queries are sketched through it); shape mismatches fail
    /// with an error rather than silently mis-scoring.
    pub fn from_snapshot(
        snap: &StoreSnapshot,
        spec: ProjectionSpec,
        p: usize,
    ) -> anyhow::Result<(Self, Vec<u64>)> {
        let (idx, ids, _) = Self::from_snapshot_incremental(snap, spec, p, None)?;
        Ok((idx, ids))
    }

    /// [`KnnIndex::from_snapshot`] with incremental refresh: segment
    /// shards of `prev` whose block `Arc` still backs the new snapshot
    /// are reused as-is — only segments newer than the previous index's
    /// epoch (fresh ingests, compaction outputs) pay the per-segment
    /// norm gather. The third return is the number of segments
    /// (re-)indexed, the `knn_segments_reindexed` metric.
    pub fn from_snapshot_incremental(
        snap: &StoreSnapshot,
        spec: ProjectionSpec,
        p: usize,
        prev: Option<&KnnIndex>,
    ) -> anyhow::Result<(Self, Vec<u64>, usize)> {
        let dec = Decomposition::new(p)?;
        let k = spec.k;
        let sketcher = Sketcher::new(spec, p);
        let prev_shards: &[Shard] = match prev.map(|ix| &ix.backing) {
            Some(Backing::Shared(sp)) => &sp.shards,
            _ => &[],
        };
        let map_ids = snap.map_ids();
        if let Some(rs) = map_ids.first().and_then(|&id| snap.map_row(id)) {
            anyhow::ensure!(
                rs.uside.k == k && rs.uside.orders == p - 1,
                "snapshot shape (k={}, orders={}) does not match index spec (k={}, p={})",
                rs.uside.k,
                rs.uside.orders,
                k,
                p,
            );
        }
        let mut shards: Vec<Shard> = Vec::new();
        let mut ids: Vec<u64> = Vec::new();
        let mut off = 0usize;
        let mut reindexed = 0usize;
        let mut mi = 0usize;
        // Close out the run of map ids below `limit` as one Map shard.
        let mut flush_map = |upto: u64,
                             mi: &mut usize,
                             off: &mut usize,
                             shards: &mut Vec<Shard>,
                             ids: &mut Vec<u64>|
         -> anyhow::Result<()> {
            let start = *mi;
            while *mi < map_ids.len() && map_ids[*mi] < upto {
                *mi += 1;
            }
            if *mi > start {
                let mut rows = Vec::with_capacity(*mi - start);
                for &id in &map_ids[start..*mi] {
                    let rs = snap
                        .map_row(id)
                        .ok_or_else(|| anyhow::anyhow!("snapshot map id {id} vanished"))?;
                    rows.push(rs);
                    ids.push(id);
                }
                shards.push(Shard::Map { off: *off, rows });
                *off += *mi - start;
            }
            Ok(())
        };
        for seg in snap.segments() {
            let rows = seg.block.rows();
            let end = seg.base + rows as u64;
            flush_map(seg.base, &mut mi, &mut off, &mut shards, &mut ids)?;
            anyhow::ensure!(
                mi == map_ids.len() || map_ids[mi] >= end,
                "store id {} present in both map and columnar segments",
                map_ids[mi],
            );
            anyhow::ensure!(
                seg.block.k() == k && seg.block.orders() == p - 1,
                "segment shape (k={}, orders={}) does not match index spec (k={}, p={})",
                seg.block.k(),
                seg.block.orders(),
                k,
                p,
            );
            let reused = prev_shards.iter().find_map(|s| match s {
                Shard::Seg(ss) if Arc::ptr_eq(&ss.block, &seg.block) => Some(ss.clone()),
                _ => None,
            });
            let shard = match reused {
                Some(ss) => SegShard { off, ..ss },
                None => {
                    reindexed += 1;
                    let norms: Vec<f64> = (0..rows).map(|r| seg.block.moment(r, p)).collect();
                    SegShard {
                        off,
                        base: seg.base,
                        block: Arc::clone(&seg.block),
                        zone: Arc::clone(&seg.zone),
                        norms: Arc::new(norms),
                    }
                }
            };
            shards.push(Shard::Seg(shard));
            ids.extend(seg.base..end);
            off += rows;
        }
        flush_map(u64::MAX, &mut mi, &mut off, &mut shards, &mut ids)?;
        let workers = std::thread::available_parallelism().map_or(1, |n| n.get());
        Ok((
            KnnIndex {
                dec,
                sketcher,
                backing: Backing::Shared(SharedPanels { p, k, n: off, shards }),
                use_mle: false,
                workers,
            },
            ids,
            reindexed,
        ))
    }

    pub fn len(&self) -> usize {
        match &self.backing {
            Backing::Owned { rows, .. } => rows.len(),
            Backing::Shared(sp) => sp.n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Sketch bytes *owned* by the index (the O(nk) storage claim). An
    /// Owned backing holds the sketches twice (per-row + arena); a
    /// Shared backing owns only the packed per-segment norm vectors —
    /// the panels belong to the snapshot.
    pub fn bytes(&self) -> usize {
        match &self.backing {
            Backing::Owned { rows, arena } => {
                rows.iter().map(|r| r.sketch_bytes()).sum::<usize>() + arena.bytes()
            }
            Backing::Shared(sp) => sp
                .shards
                .iter()
                .map(|s| match s {
                    Shard::Map { .. } => 0,
                    Shard::Seg(ss) => ss.norms.len() * std::mem::size_of::<f64>(),
                })
                .sum(),
        }
    }

    /// Run `f` on index row `i`'s sketch: by reference where one is
    /// resident (Owned rows, Shared map rows), materialized on demand
    /// for segment rows.
    fn with_row<T>(&self, i: usize, f: impl FnOnce(&RowSketch) -> T) -> T {
        match &self.backing {
            Backing::Owned { rows, .. } => f(&rows[i]),
            Backing::Shared(sp) => match sp.shard_for(i) {
                (Shard::Map { rows, .. }, r) => f(&rows[r]),
                (Shard::Seg(ss), r) => f(&ss.block.to_row_sketch(r)),
            },
        }
    }

    /// The stored sketch of index row `i` (`Neighbor::index` space),
    /// materialized. Prefer [`KnnIndex::query_pos`] for by-stored-id
    /// top-k — it serves the row straight from the panels instead.
    pub fn sketch_at(&self, i: usize) -> RowSketch {
        self.with_row(i, |r| r.clone())
    }

    /// Phase-1 query: top `m` candidates by estimated distance.
    pub fn query(&self, q: &[f32], m: usize) -> Vec<Neighbor> {
        self.query_batch(&[q], m).pop().unwrap_or_default()
    }

    /// Batched phase-1 queries: sketch the whole batch at once, then
    /// run [`KnnIndex::query_sketches`].
    pub fn query_batch(&self, qs: &[&[f32]], m: usize) -> Vec<Vec<Neighbor>> {
        if qs.is_empty() {
            return Vec::new();
        }
        self.query_sketches(&self.sketcher.sketch_rows(qs), m)
    }

    /// Batched phase-1 queries from *already-sketched* rows (a stored
    /// row's sketch, a sketch that arrived over the wire, …): the fused
    /// zone-pruned top-k scan sharded across `self.workers` threads.
    /// Equivalent to calling [`KnnIndex::query_per_row`] per query
    /// (bitwise-identical scores), but tiled, pruned, and parallel.
    pub fn query_sketches(&self, qsk: &[RowSketch], m: usize) -> Vec<Vec<Neighbor>> {
        self.query_sketches_stats(qsk, m).0
    }

    /// [`KnnIndex::query_sketches`] plus the pruning counters of the
    /// underlying zoned scan (zeros in MLE mode, which scans per-row).
    pub fn query_sketches_stats(
        &self,
        qsk: &[RowSketch],
        m: usize,
    ) -> (Vec<Vec<Neighbor>>, PruneStats) {
        if qsk.is_empty() {
            return (Vec::new(), PruneStats::default());
        }
        if self.use_mle {
            let lists = qsk.iter().map(|qrow| self.scored_per_row(qrow, m)).collect();
            return (lists, PruneStats::default());
        }
        let qarena = SketchArena::from_rows(self.dec.p(), self.sketcher.spec.k, qsk);
        self.scan(&qarena, m)
    }

    /// By-position query: index row `pos` queries the rest of the index
    /// with its own stored sketches, served directly from the backing
    /// panels — no materialization, no query arena. Bitwise-identical
    /// to `query_sketches(&[self.sketch_at(pos)], m)`. Out-of-range
    /// positions return an empty list.
    pub fn query_pos(&self, pos: usize, m: usize) -> Vec<Neighbor> {
        self.query_pos_stats(pos, m).0
    }

    /// [`KnnIndex::query_pos`] plus the pruning counters.
    pub fn query_pos_stats(&self, pos: usize, m: usize) -> (Vec<Neighbor>, PruneStats) {
        if pos >= self.len() {
            return (Vec::new(), PruneStats::default());
        }
        if self.use_mle {
            let qs = self.sketch_at(pos);
            return (self.scored_per_row(&qs, m), PruneStats::default());
        }
        let (mut lists, stats) = match &self.backing {
            Backing::Owned { arena, .. } => self.scan(&OneRow { inner: arena, row: pos }, m),
            Backing::Shared(sp) => self.scan(&OneRow { inner: sp, row: pos }, m),
        };
        (lists.pop().unwrap_or_default(), stats)
    }

    /// The zoned top-k scan over this index's backing. Owned backings
    /// scan as one zoneless extent (nothing to prune); Shared backings
    /// prune segments via their zone bounds. Results are
    /// bitwise-identical either way.
    fn scan<Q: SketchPanels>(&self, q: &Q, m: usize) -> (Vec<Vec<Neighbor>>, PruneStats) {
        let workers = self.workers.max(1);
        let (lists, stats) = match &self.backing {
            Backing::Owned { arena, .. } => {
                let whole = [ZoneExtent { off: 0, rows: arena.n(), zone: None }];
                estimator::top_k_scan_zoned(&self.dec, q, arena, &whole, m, workers)
            }
            Backing::Shared(sp) => {
                let extents = sp.extents();
                estimator::top_k_scan_zoned(&self.dec, q, sp, &extents, m, workers)
            }
        };
        let lists = lists
            .into_iter()
            .map(|lst| {
                lst.into_iter()
                    .map(|(index, distance)| Neighbor { index, distance, exact: false })
                    .collect()
            })
            .collect();
        (lists, stats)
    }

    /// Reference per-row query path: score every stored row one pair at
    /// a time, then select. Used by the MLE mode, by tests as the arena
    /// oracle, and by E8/hotpath as the per-row baseline arm.
    pub fn query_per_row(&self, q: &[f32], m: usize) -> Vec<Neighbor> {
        let qs = self.sketcher.sketch_row(q);
        self.scored_per_row(&qs, m)
    }

    fn scored_per_row(&self, qs: &RowSketch, m: usize) -> Vec<Neighbor> {
        let mut scored: Vec<Neighbor> = (0..self.len())
            .map(|i| Neighbor {
                index: i,
                distance: self.with_row(i, |r| {
                    if self.use_mle {
                        mle::estimate_mle(&self.dec, qs, r, Solve::OneStepNewton)
                    } else {
                        estimator::estimate(&self.dec, qs, r)
                    }
                }),
                exact: false,
            })
            .collect();
        top_m(&mut scored, m)
    }

    /// Two-phase query: take `rerank ≥ m` candidates by sketch, then
    /// re-rank those with exact distances over `data` (must be the same
    /// matrix the index was built from).
    pub fn query_rerank(
        &self,
        data: &RowMatrix,
        q: &[f32],
        m: usize,
        rerank: usize,
    ) -> Vec<Neighbor> {
        assert_eq!(data.n(), self.len(), "index/data mismatch");
        let cands = self.query(q, rerank.max(m));
        let p = self.dec.p();
        let mut exact: Vec<Neighbor> = cands
            .into_iter()
            .map(|c| Neighbor {
                index: c.index,
                distance: crate::baselines::exact::distance_f32(q, data.row(c.index), p),
                exact: true,
            })
            .collect();
        top_m(&mut exact, m)
    }
}

/// Exact top-m by full scan (ground truth for recall).
pub fn exact_knn(data: &RowMatrix, q: &[f32], m: usize, p: usize) -> Vec<Neighbor> {
    let mut scored: Vec<Neighbor> = (0..data.n())
        .map(|i| Neighbor {
            index: i,
            distance: crate::baselines::exact::distance_f32(q, data.row(i), p),
            exact: true,
        })
        .collect();
    top_m(&mut scored, m)
}

/// recall@m of `got` against ground truth `truth` (both top-m lists).
pub fn recall(got: &[Neighbor], truth: &[Neighbor]) -> f64 {
    if truth.is_empty() {
        return 1.0;
    }
    let truth_set: std::collections::HashSet<usize> = truth.iter().map(|n| n.index).collect();
    let hit = got.iter().filter(|n| truth_set.contains(&n.index)).count();
    hit as f64 / truth.len() as f64
}

/// Select the `m` nearest of `scored`, ascending by distance (ties by
/// index). NaN distances are dropped, and empty/short inputs yield an
/// empty/short list instead of panicking (`select_nth_unstable_by` on an
/// empty slice, or `partial_cmp().unwrap()` on NaN, were both seed
/// crashes here).
fn top_m(scored: &mut Vec<Neighbor>, m: usize) -> Vec<Neighbor> {
    scored.retain(|n| !n.distance.is_nan());
    let m = m.min(scored.len());
    if m == 0 {
        return Vec::new();
    }
    if m < scored.len() {
        scored.select_nth_unstable_by(m - 1, |a, b| {
            a.distance.total_cmp(&b.distance).then(a.index.cmp(&b.index))
        });
    }
    scored.truncate(m);
    scored.sort_by(|a, b| a.distance.total_cmp(&b.distance).then(a.index.cmp(&b.index)));
    std::mem::take(scored)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::data::{corpus, gen, DataDist};
    use crate::projection::{ProjectionDist, Strategy};

    fn spec(k: usize) -> ProjectionSpec {
        ProjectionSpec::new(99, k, ProjectionDist::Normal, Strategy::Basic)
    }

    #[test]
    fn exact_knn_finds_self_first() {
        let data = gen::generate(DataDist::Uniform01, 30, 32, 4);
        let got = exact_knn(&data, data.row(7), 3, 4);
        assert_eq!(got[0].index, 7);
        assert_eq!(got[0].distance, 0.0);
    }

    #[test]
    fn rerank_recall_dominates_sketch_only() {
        let data = corpus::generate(200, 128, 60, 11).tf;
        let idx = KnnIndex::build(&data, spec(32), 4).unwrap();
        let mut r_sketch = 0.0;
        let mut r_rerank = 0.0;
        let queries = 20;
        for qi in 0..queries {
            let q = data.row(qi * 7 % data.n()).to_vec();
            let truth = exact_knn(&data, &q, 10, 4);
            r_sketch += recall(&idx.query(&q, 10), &truth);
            r_rerank += recall(&idx.query_rerank(&data, &q, 10, 40), &truth);
        }
        r_sketch /= queries as f64;
        r_rerank /= queries as f64;
        assert!(r_rerank >= r_sketch, "rerank {r_rerank} < sketch {r_sketch}");
        assert!(r_rerank > 0.8, "rerank recall too low: {r_rerank}");
    }

    #[test]
    fn wider_sketch_improves_recall() {
        let data = corpus::generate(150, 128, 60, 13).tf;
        let mut recalls = Vec::new();
        for k in [8usize, 128] {
            let idx = KnnIndex::build(&data, spec(k), 4).unwrap();
            let mut r = 0.0;
            let queries = 15;
            for qi in 0..queries {
                let q = data.row(qi * 5 % data.n()).to_vec();
                let truth = exact_knn(&data, &q, 10, 4);
                r += recall(&idx.query(&q, 10), &truth);
            }
            recalls.push(r / queries as f64);
        }
        assert!(
            recalls[1] > recalls[0],
            "recall should grow with k: {recalls:?}"
        );
    }

    #[test]
    fn index_bytes_scale_with_k_not_d() {
        let data = gen::generate(DataDist::Uniform01, 20, 2048, 5);
        let small = KnnIndex::build(&data, spec(16), 4).unwrap();
        let big = KnnIndex::build(&data, spec(64), 4).unwrap();
        assert!(big.bytes() > 3 * small.bytes());
        assert!(big.bytes() < data.bytes(), "sketches must compress vs O(nD)");
    }

    #[test]
    fn recall_of_identical_lists_is_one() {
        let data = gen::generate(DataDist::Uniform01, 10, 16, 6);
        let truth = exact_knn(&data, data.row(0), 5, 4);
        assert_eq!(recall(&truth, &truth), 1.0);
    }

    #[test]
    fn arena_query_matches_per_row_reference() {
        for strategy in [Strategy::Basic, Strategy::Alternative] {
            let data = gen::generate(DataDist::LogNormal { sigma: 1.0 }, 90, 64, 17);
            let idx = KnnIndex::build(
                &data,
                ProjectionSpec::new(3, 24, ProjectionDist::Normal, strategy),
                4,
            )
            .unwrap();
            let q = data.row(5).to_vec();
            let arena = idx.query(&q, 12);
            let per_row = idx.query_per_row(&q, 12);
            assert_eq!(arena.len(), per_row.len());
            for (a, b) in arena.iter().zip(&per_row) {
                assert_eq!(a.index, b.index, "{strategy:?}");
                assert!((a.distance - b.distance).abs() <= 1e-12 * b.distance.abs().max(1.0));
            }
        }
    }

    #[test]
    fn batch_query_matches_individual_queries() {
        let data = gen::generate(DataDist::Uniform01, 70, 48, 19);
        let idx = KnnIndex::build(&data, spec(16), 4).unwrap();
        let qs: Vec<Vec<f32>> = (0..7).map(|i| data.row(i * 9).to_vec()).collect();
        let refs: Vec<&[f32]> = qs.iter().map(|v| v.as_slice()).collect();
        let batch = idx.query_batch(&refs, 5);
        assert_eq!(batch.len(), 7);
        for (q, got) in refs.iter().zip(&batch) {
            assert_eq!(got, &idx.query(q, 5));
        }
    }

    #[test]
    fn query_sketches_matches_vector_queries() {
        // Pre-sketched queries (the by-stored-id serving path) must
        // rank bitwise-identically to sketching the raw vector — the
        // stored row's own sketch IS the query payload.
        let data = gen::generate(DataDist::Gaussian, 50, 48, 23);
        let idx = KnnIndex::build(&data, spec(16), 4).unwrap();
        let q5 = idx.sketch_at(5);
        let q11 = idx.sketch_at(11);
        let by_sketch = idx.query_sketches(&[q5, q11], 6);
        assert_eq!(by_sketch[0], idx.query(data.row(5), 6));
        assert_eq!(by_sketch[1], idx.query(data.row(11), 6));
        // Self is its own nearest neighbor by stored sketch (distance
        // exactly the estimator's self-distance).
        assert_eq!(by_sketch[0][0].index, 5);
        assert!(idx.query_sketches(&[], 6).is_empty());
        // query_pos serves the same answers straight from the panels.
        assert_eq!(idx.query_pos(5, 6), by_sketch[0]);
        assert_eq!(idx.query_pos(11, 6), by_sketch[1]);
        assert!(idx.query_pos(usize::MAX, 6).is_empty());
    }

    #[test]
    fn snapshot_rebuild_matches_store_served_top_k() {
        // An index rebuilt from a pipeline's store snapshot must rank
        // exactly like the pipeline's own store-served top-k — same
        // ids, same distances — and keep serving that epoch even while
        // the store ingests more rows.
        let mut c = crate::config::Config::default();
        c.n = 60;
        c.d = 64;
        c.k = 24;
        c.block_rows = 16;
        c.workers = 2;
        let data = gen::generate(DataDist::Gaussian, c.n, c.d, 31);
        let pipeline = crate::coordinator::Pipeline::new(c.clone()).unwrap();
        pipeline.ingest(&data).unwrap();
        let snap = pipeline.store_snapshot();
        let (idx, ids) = KnnIndex::from_snapshot(&snap, c.projection_spec(), c.p).unwrap();
        assert_eq!(idx.len(), 60);
        let queries: Vec<&[f32]> = (0..3).map(|i| data.row(i * 19)).collect();
        let want = pipeline.top_k(&queries, 8).unwrap();
        // The store keeps ingesting; the rebuilt index still serves the
        // captured epoch.
        pipeline.ingest(&data).unwrap();
        let got = idx.query_batch(&queries, 8);
        for (qi, lst) in got.iter().enumerate() {
            let mapped: Vec<(u64, f64)> =
                lst.iter().map(|nb| (ids[nb.index], nb.distance)).collect();
            assert_eq!(mapped, want[qi], "query {qi}");
        }
        // Shape mismatch is an error, not silent mis-scoring.
        let bad = ProjectionSpec::new(1, c.k / 2, ProjectionDist::Normal, Strategy::Basic);
        assert!(KnnIndex::from_snapshot(&snap, bad, c.p).is_err());
    }

    #[test]
    fn shared_index_serves_from_snapshot_panels_without_copying() {
        // The double-residency fix, ptr_eq-pinned: a snapshot-backed
        // index holds the snapshot's own Arc allocations — segment
        // panels and zones are shared, never copied.
        let mut c = crate::config::Config::default();
        c.n = 48;
        c.d = 32;
        c.k = 16;
        c.block_rows = 16;
        c.workers = 2;
        let data = gen::generate(DataDist::Gaussian, c.n, c.d, 37);
        let pipeline = crate::coordinator::Pipeline::new(c.clone()).unwrap();
        pipeline.ingest(&data).unwrap();
        let snap = pipeline.store_snapshot();
        let (idx, ids) = KnnIndex::from_snapshot(&snap, c.projection_spec(), c.p).unwrap();
        assert_eq!(ids.len(), 48);
        let Backing::Shared(sp) = &idx.backing else {
            panic!("snapshot rebuild must produce a Shared backing");
        };
        let segs: Vec<&SegShard> = sp
            .shards
            .iter()
            .filter_map(|s| match s {
                Shard::Seg(ss) => Some(ss),
                Shard::Map { .. } => None,
            })
            .collect();
        assert_eq!(segs.len(), snap.segment_count());
        for (ss, seg) in segs.iter().zip(snap.segments()) {
            assert!(Arc::ptr_eq(&ss.block, &seg.block), "panels copied at base {}", seg.base);
            assert!(Arc::ptr_eq(&ss.zone, &seg.zone), "zone copied at base {}", seg.base);
            assert_eq!(ss.base, seg.base);
        }
        // Owned overhead is just the packed norms — far below the
        // payload the old arena copy duplicated.
        assert_eq!(idx.bytes(), 48 * std::mem::size_of::<f64>());
        // By-position queries served from the shared panels match the
        // materialize-then-query path bitwise.
        for pos in [0usize, 17, 47] {
            assert_eq!(
                idx.query_pos(pos, 6),
                idx.query_sketches(&[idx.sketch_at(pos)], 6)[0],
                "pos {pos}"
            );
        }
    }

    #[test]
    fn incremental_refresh_reindexes_only_new_segments() {
        let mut c = crate::config::Config::default();
        c.n = 32;
        c.d = 32;
        c.k = 16;
        c.block_rows = 16;
        c.workers = 2;
        let data = gen::generate(DataDist::Gaussian, c.n, c.d, 41);
        let pipeline = crate::coordinator::Pipeline::new(c.clone()).unwrap();
        pipeline.ingest(&data).unwrap();
        let snap1 = pipeline.store_snapshot();
        let (idx1, _, built1) =
            KnnIndex::from_snapshot_incremental(&snap1, c.projection_spec(), c.p, None).unwrap();
        assert_eq!(built1, snap1.segment_count());
        assert!(built1 > 0);
        // Appending ingest: only the new segments are indexed; the old
        // shards are reused Arc-for-Arc (norms included).
        pipeline.ingest(&data).unwrap();
        let snap2 = pipeline.store_snapshot();
        let (idx2, ids2, built2) =
            KnnIndex::from_snapshot_incremental(&snap2, c.projection_spec(), c.p, Some(&idx1))
                .unwrap();
        assert_eq!(built2, snap2.segment_count() - snap1.segment_count());
        assert!(built2 > 0);
        let shards_of = |ix: &KnnIndex| match &ix.backing {
            Backing::Shared(sp) => sp
                .shards
                .iter()
                .filter_map(|s| match s {
                    Shard::Seg(ss) => Some(ss.clone()),
                    Shard::Map { .. } => None,
                })
                .collect::<Vec<_>>(),
            Backing::Owned { .. } => panic!("expected shared backing"),
        };
        let (s1, s2) = (shards_of(&idx1), shards_of(&idx2));
        for old in &s1 {
            let carried = s2
                .iter()
                .find(|ss| Arc::ptr_eq(&ss.block, &old.block))
                .expect("unchanged segment dropped from refreshed index");
            assert!(Arc::ptr_eq(&carried.norms, &old.norms), "norms rebuilt at {}", old.base);
        }
        // The refreshed index answers bitwise-equal to a cold rebuild.
        let (cold, cold_ids) =
            KnnIndex::from_snapshot(&snap2, c.projection_spec(), c.p).unwrap();
        assert_eq!(ids2, cold_ids);
        for pos in [0usize, 20, 63] {
            assert_eq!(idx2.query_pos(pos, 7), cold.query_pos(pos, 7), "pos {pos}");
        }
        let q = data.row(3);
        assert_eq!(idx2.query(q, 9), cold.query(q, 9));
        // An unchanged snapshot refresh re-indexes nothing.
        let (_, _, built3) =
            KnnIndex::from_snapshot_incremental(&snap2, c.projection_spec(), c.p, Some(&idx2))
                .unwrap();
        assert_eq!(built3, 0);
    }

    #[test]
    fn shared_backing_serves_mixed_map_and_segment_stores() {
        use crate::coordinator::SketchStore;
        use crate::projection::sketcher::Sketcher;
        // Map rows interleaved around a columnar segment: the shard walk
        // must tile the id space exactly and score identically to an
        // Owned index over the same sketches.
        let sk = Sketcher::new(spec(12), 4);
        let data = gen::generate(DataDist::Gaussian, 12, 24, 43);
        let refs: Vec<&[f32]> = (0..12).map(|i| data.row(i)).collect();
        let store = SketchStore::new(3);
        // ids 0,1 and 20 in the map; 8..16 columnar (rows 2..10).
        store.insert(0, sk.sketch_row(refs[0]));
        store.insert(1, sk.sketch_row(refs[1]));
        store.insert_block_columnar(8, sk.sketch_block(&refs[2..10], 1));
        store.insert(20, sk.sketch_row(refs[10]));
        let snap = store.snapshot();
        let (idx, ids) = KnnIndex::from_snapshot(&snap, spec(12), 4).unwrap();
        assert_eq!(ids, vec![0, 1, 8, 9, 10, 11, 12, 13, 14, 15, 20]);
        assert_eq!(idx.len(), 11);
        // Owned oracle over the same rows in id order (map run 0,1 —
        // then segment rows 2..10 — then map row 10 at id 20).
        let flat: Vec<f32> = (0..11).flat_map(|i| refs[i].iter().copied()).collect();
        let owned = KnnIndex::build(&RowMatrix::new(11, 24, flat), spec(12), 4).unwrap();
        for qi in [0usize, 5, 11] {
            let got = idx.query(refs[qi], 6);
            let want = owned.query(refs[qi], 6);
            assert_eq!(got, want, "query row {qi}");
        }
        // By-position works for map rows and segment rows alike.
        for pos in 0..idx.len() {
            assert_eq!(
                idx.query_pos(pos, 4),
                idx.query_sketches(&[idx.sketch_at(pos)], 4)[0],
                "pos {pos}"
            );
        }
    }

    #[test]
    fn empty_index_returns_empty_results() {
        let data = RowMatrix::zeros(0, 16);
        let idx = KnnIndex::build(&data, spec(8), 4).unwrap();
        assert!(idx.is_empty());
        let q = vec![1.0f32; 16];
        assert!(idx.query(&q, 5).is_empty());
        assert!(idx.query_per_row(&q, 5).is_empty());
        assert!(idx.query_rerank(&data, &q, 5, 10).is_empty());
        assert!(idx.query_pos(0, 5).is_empty());
        let mut mle_idx = KnnIndex::build(&data, spec(8), 4).unwrap();
        mle_idx.use_mle = true;
        assert!(mle_idx.query(&q, 5).is_empty());
        // An empty snapshot builds an empty shared index.
        let store = crate::coordinator::SketchStore::new(2);
        let (idx, ids) = KnnIndex::from_snapshot(&store.snapshot(), spec(8), 4).unwrap();
        assert!(idx.is_empty());
        assert!(ids.is_empty());
        assert!(idx.query(&q, 5).is_empty());
    }

    #[test]
    fn top_m_filters_nan_and_handles_short_inputs() {
        let nb = |index, distance| Neighbor { index, distance, exact: false };
        // Empty input, any m.
        assert!(top_m(&mut Vec::new(), 3).is_empty());
        // NaNs dropped, remainder ordered, ties broken by index.
        let mut scored = vec![
            nb(0, f64::NAN),
            nb(1, 2.0),
            nb(2, 1.0),
            nb(3, f64::NAN),
            nb(4, 1.0),
        ];
        let got = top_m(&mut scored, 10);
        assert_eq!(
            got.iter().map(|n| n.index).collect::<Vec<_>>(),
            vec![2, 4, 1]
        );
        // m = 0.
        let mut scored = vec![nb(0, 1.0)];
        assert!(top_m(&mut scored, 0).is_empty());
        // m larger than the (post-filter) input.
        let mut scored = vec![nb(0, f64::NAN), nb(1, 3.0)];
        let got = top_m(&mut scored, 5);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].index, 1);
    }
}
