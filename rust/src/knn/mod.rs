//! Sketch-based k-nearest-neighbor search — the paper's introductory
//! use-case ("a straightforward application would be searching for the
//! nearest neighbors using l_p distance").
//!
//! Two-phase search, the standard sketch-index pattern:
//! 1. **Candidate generation** — rank all rows by the *estimated* l_p
//!    distance from the query's sketch (O(n·k) per query instead of
//!    O(n·D)).
//! 2. **Re-ranking (optional)** — recompute exact distances for the top
//!    `rerank` candidates with a linear scan over just those rows.
//!
//! ## Index layout and the blocked query path
//!
//! The index keeps its sketches twice:
//! * a [`SketchArena`] — columnar (order-major `orders × (n × k)`)
//!   storage the plain-estimator queries run on. [`KnnIndex::query`] and
//!   [`KnnIndex::query_batch`] route through
//!   [`estimator::top_k_scan_arena`]: target rows stream in
//!   cache-sized tiles through a bounded per-query heap, and query
//!   batches are sharded across `workers` threads via
//!   `std::thread::scope`. Scores are bitwise-identical to the per-row
//!   reference path ([`KnnIndex::query_per_row`]).
//! * the per-row [`RowSketch`]es — kept for the margin-MLE scoring mode
//!   (`use_mle`), which consumes per-order norms and higher moments the
//!   arena does not store.
//!
//! NaN scores (malformed input rows) are filtered, never returned; an
//! empty index returns empty neighbor lists rather than panicking.
//!
//! E8 measures recall@m vs sketch width k, with and without re-ranking,
//! against exact ground truth, plus the arena-vs-per-row batch timing.

use crate::coordinator::StoreSnapshot;
use crate::core::arena::SketchArena;
use crate::core::decompose::Decomposition;
use crate::core::estimator;
use crate::core::mle::{self, Solve};
use crate::data::RowMatrix;
use crate::projection::sketcher::{RowSketch, Sketcher};
use crate::projection::ProjectionSpec;

/// A built sketch index over a fixed row set.
///
/// Memory note: the sketches are held twice — per-row (the MLE path
/// consumes per-order norms/moments the arena does not store, and
/// `use_mle` may be toggled on at any time after build) and columnar.
/// That doubles the O(nk) payload; an MLE-free, single-copy index is a
/// follow-up once `use_mle` becomes a build-time choice.
pub struct KnnIndex {
    dec: Decomposition,
    sketcher: Sketcher,
    rows: Vec<RowSketch>,
    arena: SketchArena,
    /// Use the margin MLE (Lemma 4) when scoring candidates (per-row
    /// scoring path; the arena kernels serve the plain estimator).
    pub use_mle: bool,
    /// Threads used to shard batched queries (defaults to the machine's
    /// available parallelism).
    pub workers: usize,
}

/// One scored neighbor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Neighbor {
    pub index: usize,
    /// Estimated (phase 1) or exact (after re-rank) l_p^p distance.
    pub distance: f64,
    pub exact: bool,
}

impl KnnIndex {
    /// Sketch every row of `data` (the index build = one linear scan)
    /// and transpose the sketches into the columnar arena.
    pub fn build(data: &RowMatrix, spec: ProjectionSpec, p: usize) -> anyhow::Result<Self> {
        let dec = Decomposition::new(p)?;
        let k = spec.k;
        let sketcher = Sketcher::new(spec, p);
        let refs: Vec<&[f32]> = (0..data.n()).map(|i| data.row(i)).collect();
        let rows = sketcher.sketch_rows(&refs);
        let arena = SketchArena::from_rows(p, k, &rows);
        let workers = std::thread::available_parallelism().map_or(1, |n| n.get());
        Ok(KnnIndex { dec, sketcher, rows, arena, use_mle: false, workers })
    }

    /// Rebuild an index from a store snapshot — the serving-side
    /// rebuild: the index is assembled entirely from the O(nk) sketch
    /// state of one consistent epoch cut, while ingest keeps writing to
    /// the live store underneath. Returns the index plus the store id
    /// of every index row (`Neighbor::index` i ↔ `ids[i]`).
    ///
    /// `spec` must be the projection the store's sketches were built
    /// with (queries are sketched through it); shape mismatches fail
    /// with an error rather than silently mis-scoring.
    pub fn from_snapshot(
        snap: &StoreSnapshot,
        spec: ProjectionSpec,
        p: usize,
    ) -> anyhow::Result<(Self, Vec<u64>)> {
        let dec = Decomposition::new(p)?;
        let k = spec.k;
        let sketcher = Sketcher::new(spec, p);
        let ids = snap.ids();
        // Shape check before the arena build (which would panic on a
        // mismatched row).
        if let Some(rs) = ids.first().map(|&id| snap.get(id).expect("snapshot listed id")) {
            anyhow::ensure!(
                rs.uside.k == k && rs.uside.orders == p - 1,
                "snapshot shape (k={}, orders={}) does not match index spec (k={}, p={})",
                rs.uside.k,
                rs.uside.orders,
                k,
                p,
            );
        }
        let arena_snap = snap.arena(p, k);
        let rows: Vec<RowSketch> = arena_snap
            .ids
            .iter()
            .map(|&id| snap.get(id).expect("snapshot listed id"))
            .collect();
        let workers = std::thread::available_parallelism().map_or(1, |n| n.get());
        Ok((
            KnnIndex { dec, sketcher, rows, arena: arena_snap.arena, use_mle: false, workers },
            arena_snap.ids,
        ))
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Sketch bytes held by the index (the O(nk) storage claim): per-row
    /// sketches plus the columnar arena mirror.
    pub fn bytes(&self) -> usize {
        self.rows.iter().map(|r| r.sketch_bytes()).sum::<usize>() + self.arena.bytes()
    }

    /// The stored sketch of index row `i` (`Neighbor::index` space) —
    /// the query payload for by-stored-id top-k, where the row's own
    /// sketch ranks the rest of the index with no raw data and no
    /// re-sketching.
    pub fn sketch_at(&self, i: usize) -> &RowSketch {
        &self.rows[i]
    }

    /// Phase-1 query: top `m` candidates by estimated distance.
    pub fn query(&self, q: &[f32], m: usize) -> Vec<Neighbor> {
        self.query_batch(&[q], m).pop().unwrap_or_default()
    }

    /// Batched phase-1 queries: sketch the whole batch at once, then
    /// run [`KnnIndex::query_sketches`].
    pub fn query_batch(&self, qs: &[&[f32]], m: usize) -> Vec<Vec<Neighbor>> {
        if qs.is_empty() {
            return Vec::new();
        }
        self.query_sketches(&self.sketcher.sketch_rows(qs), m)
    }

    /// Batched phase-1 queries from *already-sketched* rows (a stored
    /// row's sketch, a sketch that arrived over the wire, …): the fused
    /// arena top-k scan sharded across `self.workers` threads.
    /// Equivalent to calling [`KnnIndex::query_per_row`] per query
    /// (bitwise-identical scores), but tiled and parallel.
    pub fn query_sketches(&self, qsk: &[RowSketch], m: usize) -> Vec<Vec<Neighbor>> {
        if qsk.is_empty() {
            return Vec::new();
        }
        if self.use_mle {
            return qsk.iter().map(|qrow| self.scored_per_row(qrow, m)).collect();
        }
        let qarena = SketchArena::from_rows(self.dec.p(), self.sketcher.spec.k, qsk);
        estimator::top_k_scan_arena(&self.dec, &qarena, &self.arena, m, self.workers.max(1))
            .into_iter()
            .map(|lst| {
                lst.into_iter()
                    .map(|(index, distance)| Neighbor { index, distance, exact: false })
                    .collect()
            })
            .collect()
    }

    /// Reference per-row query path: score every stored row one pair at
    /// a time, then select. Used by the MLE mode, by tests as the arena
    /// oracle, and by E8/hotpath as the per-row baseline arm.
    pub fn query_per_row(&self, q: &[f32], m: usize) -> Vec<Neighbor> {
        let qs = self.sketcher.sketch_row(q);
        self.scored_per_row(&qs, m)
    }

    fn scored_per_row(&self, qs: &RowSketch, m: usize) -> Vec<Neighbor> {
        let mut scored: Vec<Neighbor> = self
            .rows
            .iter()
            .enumerate()
            .map(|(i, r)| Neighbor {
                index: i,
                distance: if self.use_mle {
                    mle::estimate_mle(&self.dec, qs, r, Solve::OneStepNewton)
                } else {
                    estimator::estimate(&self.dec, qs, r)
                },
                exact: false,
            })
            .collect();
        top_m(&mut scored, m)
    }

    /// Two-phase query: take `rerank ≥ m` candidates by sketch, then
    /// re-rank those with exact distances over `data` (must be the same
    /// matrix the index was built from).
    pub fn query_rerank(
        &self,
        data: &RowMatrix,
        q: &[f32],
        m: usize,
        rerank: usize,
    ) -> Vec<Neighbor> {
        assert_eq!(data.n(), self.rows.len(), "index/data mismatch");
        let cands = self.query(q, rerank.max(m));
        let p = self.dec.p();
        let mut exact: Vec<Neighbor> = cands
            .into_iter()
            .map(|c| Neighbor {
                index: c.index,
                distance: crate::baselines::exact::distance_f32(q, data.row(c.index), p),
                exact: true,
            })
            .collect();
        top_m(&mut exact, m)
    }
}

/// Exact top-m by full scan (ground truth for recall).
pub fn exact_knn(data: &RowMatrix, q: &[f32], m: usize, p: usize) -> Vec<Neighbor> {
    let mut scored: Vec<Neighbor> = (0..data.n())
        .map(|i| Neighbor {
            index: i,
            distance: crate::baselines::exact::distance_f32(q, data.row(i), p),
            exact: true,
        })
        .collect();
    top_m(&mut scored, m)
}

/// recall@m of `got` against ground truth `truth` (both top-m lists).
pub fn recall(got: &[Neighbor], truth: &[Neighbor]) -> f64 {
    if truth.is_empty() {
        return 1.0;
    }
    let truth_set: std::collections::HashSet<usize> = truth.iter().map(|n| n.index).collect();
    let hit = got.iter().filter(|n| truth_set.contains(&n.index)).count();
    hit as f64 / truth.len() as f64
}

/// Select the `m` nearest of `scored`, ascending by distance (ties by
/// index). NaN distances are dropped, and empty/short inputs yield an
/// empty/short list instead of panicking (`select_nth_unstable_by` on an
/// empty slice, or `partial_cmp().unwrap()` on NaN, were both seed
/// crashes here).
fn top_m(scored: &mut Vec<Neighbor>, m: usize) -> Vec<Neighbor> {
    scored.retain(|n| !n.distance.is_nan());
    let m = m.min(scored.len());
    if m == 0 {
        return Vec::new();
    }
    if m < scored.len() {
        scored.select_nth_unstable_by(m - 1, |a, b| {
            a.distance.total_cmp(&b.distance).then(a.index.cmp(&b.index))
        });
    }
    scored.truncate(m);
    scored.sort_by(|a, b| a.distance.total_cmp(&b.distance).then(a.index.cmp(&b.index)));
    std::mem::take(scored)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{corpus, gen, DataDist};
    use crate::projection::{ProjectionDist, Strategy};

    fn spec(k: usize) -> ProjectionSpec {
        ProjectionSpec::new(99, k, ProjectionDist::Normal, Strategy::Basic)
    }

    #[test]
    fn exact_knn_finds_self_first() {
        let data = gen::generate(DataDist::Uniform01, 30, 32, 4);
        let got = exact_knn(&data, data.row(7), 3, 4);
        assert_eq!(got[0].index, 7);
        assert_eq!(got[0].distance, 0.0);
    }

    #[test]
    fn rerank_recall_dominates_sketch_only() {
        let data = corpus::generate(200, 128, 60, 11).tf;
        let idx = KnnIndex::build(&data, spec(32), 4).unwrap();
        let mut r_sketch = 0.0;
        let mut r_rerank = 0.0;
        let queries = 20;
        for qi in 0..queries {
            let q = data.row(qi * 7 % data.n()).to_vec();
            let truth = exact_knn(&data, &q, 10, 4);
            r_sketch += recall(&idx.query(&q, 10), &truth);
            r_rerank += recall(&idx.query_rerank(&data, &q, 10, 40), &truth);
        }
        r_sketch /= queries as f64;
        r_rerank /= queries as f64;
        assert!(r_rerank >= r_sketch, "rerank {r_rerank} < sketch {r_sketch}");
        assert!(r_rerank > 0.8, "rerank recall too low: {r_rerank}");
    }

    #[test]
    fn wider_sketch_improves_recall() {
        let data = corpus::generate(150, 128, 60, 13).tf;
        let mut recalls = Vec::new();
        for k in [8usize, 128] {
            let idx = KnnIndex::build(&data, spec(k), 4).unwrap();
            let mut r = 0.0;
            let queries = 15;
            for qi in 0..queries {
                let q = data.row(qi * 5 % data.n()).to_vec();
                let truth = exact_knn(&data, &q, 10, 4);
                r += recall(&idx.query(&q, 10), &truth);
            }
            recalls.push(r / queries as f64);
        }
        assert!(
            recalls[1] > recalls[0],
            "recall should grow with k: {recalls:?}"
        );
    }

    #[test]
    fn index_bytes_scale_with_k_not_d() {
        let data = gen::generate(DataDist::Uniform01, 20, 2048, 5);
        let small = KnnIndex::build(&data, spec(16), 4).unwrap();
        let big = KnnIndex::build(&data, spec(64), 4).unwrap();
        assert!(big.bytes() > 3 * small.bytes());
        assert!(big.bytes() < data.bytes(), "sketches must compress vs O(nD)");
    }

    #[test]
    fn recall_of_identical_lists_is_one() {
        let data = gen::generate(DataDist::Uniform01, 10, 16, 6);
        let truth = exact_knn(&data, data.row(0), 5, 4);
        assert_eq!(recall(&truth, &truth), 1.0);
    }

    #[test]
    fn arena_query_matches_per_row_reference() {
        for strategy in [Strategy::Basic, Strategy::Alternative] {
            let data = gen::generate(DataDist::LogNormal { sigma: 1.0 }, 90, 64, 17);
            let idx = KnnIndex::build(
                &data,
                ProjectionSpec::new(3, 24, ProjectionDist::Normal, strategy),
                4,
            )
            .unwrap();
            let q = data.row(5).to_vec();
            let arena = idx.query(&q, 12);
            let per_row = idx.query_per_row(&q, 12);
            assert_eq!(arena.len(), per_row.len());
            for (a, b) in arena.iter().zip(&per_row) {
                assert_eq!(a.index, b.index, "{strategy:?}");
                assert!((a.distance - b.distance).abs() <= 1e-12 * b.distance.abs().max(1.0));
            }
        }
    }

    #[test]
    fn batch_query_matches_individual_queries() {
        let data = gen::generate(DataDist::Uniform01, 70, 48, 19);
        let idx = KnnIndex::build(&data, spec(16), 4).unwrap();
        let qs: Vec<Vec<f32>> = (0..7).map(|i| data.row(i * 9).to_vec()).collect();
        let refs: Vec<&[f32]> = qs.iter().map(|v| v.as_slice()).collect();
        let batch = idx.query_batch(&refs, 5);
        assert_eq!(batch.len(), 7);
        for (q, got) in refs.iter().zip(&batch) {
            assert_eq!(got, &idx.query(q, 5));
        }
    }

    #[test]
    fn query_sketches_matches_vector_queries() {
        // Pre-sketched queries (the by-stored-id serving path) must
        // rank bitwise-identically to sketching the raw vector — the
        // stored row's own sketch IS the query payload.
        let data = gen::generate(DataDist::Gaussian, 50, 48, 23);
        let idx = KnnIndex::build(&data, spec(16), 4).unwrap();
        let q5 = idx.sketch_at(5).clone();
        let q11 = idx.sketch_at(11).clone();
        let by_sketch = idx.query_sketches(&[q5, q11], 6);
        assert_eq!(by_sketch[0], idx.query(data.row(5), 6));
        assert_eq!(by_sketch[1], idx.query(data.row(11), 6));
        // Self is its own nearest neighbor by stored sketch (distance
        // exactly the estimator's self-distance).
        assert_eq!(by_sketch[0][0].index, 5);
        assert!(idx.query_sketches(&[], 6).is_empty());
    }

    #[test]
    fn snapshot_rebuild_matches_store_served_top_k() {
        // An index rebuilt from a pipeline's store snapshot must rank
        // exactly like the pipeline's own store-served top-k — same
        // ids, same distances — and keep serving that epoch even while
        // the store ingests more rows.
        let mut c = crate::config::Config::default();
        c.n = 60;
        c.d = 64;
        c.k = 24;
        c.block_rows = 16;
        c.workers = 2;
        let data = gen::generate(DataDist::Gaussian, c.n, c.d, 31);
        let pipeline = crate::coordinator::Pipeline::new(c.clone()).unwrap();
        pipeline.ingest(&data).unwrap();
        let snap = pipeline.store_snapshot();
        let (idx, ids) = KnnIndex::from_snapshot(&snap, c.projection_spec(), c.p).unwrap();
        assert_eq!(idx.len(), 60);
        let queries: Vec<&[f32]> = (0..3).map(|i| data.row(i * 19)).collect();
        let want = pipeline.top_k(&queries, 8).unwrap();
        // The store keeps ingesting; the rebuilt index still serves the
        // captured epoch.
        pipeline.ingest(&data).unwrap();
        let got = idx.query_batch(&queries, 8);
        for (qi, lst) in got.iter().enumerate() {
            let mapped: Vec<(u64, f64)> =
                lst.iter().map(|nb| (ids[nb.index], nb.distance)).collect();
            assert_eq!(mapped, want[qi], "query {qi}");
        }
        // Shape mismatch is an error, not silent mis-scoring.
        let bad = ProjectionSpec::new(1, c.k / 2, ProjectionDist::Normal, Strategy::Basic);
        assert!(KnnIndex::from_snapshot(&snap, bad, c.p).is_err());
    }

    #[test]
    fn empty_index_returns_empty_results() {
        let data = RowMatrix::zeros(0, 16);
        let idx = KnnIndex::build(&data, spec(8), 4).unwrap();
        assert!(idx.is_empty());
        let q = vec![1.0f32; 16];
        assert!(idx.query(&q, 5).is_empty());
        assert!(idx.query_per_row(&q, 5).is_empty());
        assert!(idx.query_rerank(&data, &q, 5, 10).is_empty());
        let mut mle_idx = KnnIndex::build(&data, spec(8), 4).unwrap();
        mle_idx.use_mle = true;
        assert!(mle_idx.query(&q, 5).is_empty());
    }

    #[test]
    fn top_m_filters_nan_and_handles_short_inputs() {
        let nb = |index, distance| Neighbor { index, distance, exact: false };
        // Empty input, any m.
        assert!(top_m(&mut Vec::new(), 3).is_empty());
        // NaNs dropped, remainder ordered, ties broken by index.
        let mut scored = vec![
            nb(0, f64::NAN),
            nb(1, 2.0),
            nb(2, 1.0),
            nb(3, f64::NAN),
            nb(4, 1.0),
        ];
        let got = top_m(&mut scored, 10);
        assert_eq!(
            got.iter().map(|n| n.index).collect::<Vec<_>>(),
            vec![2, 4, 1]
        );
        // m = 0.
        let mut scored = vec![nb(0, 1.0)];
        assert!(top_m(&mut scored, 0).is_empty());
        // m larger than the (post-filter) input.
        let mut scored = vec![nb(0, f64::NAN), nb(1, 3.0)];
        let got = top_m(&mut scored, 5);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].index, 1);
    }
}
