//! # lpsketch
//!
//! Production reproduction of **"On Approximating the l_p Distances for
//! p > 2 (When p Is Even)"** (Ping Li, 2008): sketch-based approximation
//! of pairwise l_p distances for even p ≥ 4 in massive data matrices,
//! with a rust streaming coordinator executing JAX/Pallas AOT-compiled
//! compute via PJRT.
//!
//! Layer map (DESIGN.md §2):
//! * [`core`] — the paper's estimation theory (decomposition, estimators,
//!   margin MLE, variance Lemmas 1–6).
//! * [`projection`] — reproducible random projections (normal /
//!   sub-Gaussian) and the pure-rust sketcher.
//! * [`runtime`] — PJRT engine loading `artifacts/*.hlo.txt`.
//! * [`coordinator`] — streaming ingest pipeline, batching, routing,
//!   sketch store, metrics.
//! * [`api`] — the unified typed query surface: request/response
//!   protocol, wire codec, batched service, TCP server + client.
//! * [`data`], [`baselines`], [`knn`] — substrates: generators/IO/corpus,
//!   exact & stable-projection & sampling baselines, sketch-based k-NN.
//! * [`experiments`] — the E1..E11 reproduction harness (one per paper
//!   claim; see DESIGN.md §4).

pub mod analysis;
pub mod api;
pub mod baselines;
pub mod bench_support;
pub mod config;
pub mod coordinator;
pub mod core;
pub mod data;
pub mod experiments;
pub mod knn;
pub mod projection;
pub mod runtime;
pub mod testkit;
pub mod util;
