//! `lpsketch` — CLI for the sketch-based even-p l_p distance pipeline.
//!
//! Every query-shaped subcommand routes through the **unified typed
//! API** ([`lpsketch::api`]): requests are `PairBatch` / `TopK` (by
//! stored id or fresh vector) / `VectorDistance` / `Stats` / `Ping`,
//! answered by the batched query service from per-batch epoch
//! snapshots — in-process or over TCP, with bitwise-identical
//! estimates either way.
//!
//! Subcommands:
//!   ingest   — stream a matrix (file or synthetic) into sketches, report
//!              the scan/storage accounting (`--save-sketches` persists
//!              the O(nk) state, projection parameters included).
//!   pairs    — ingest (or `--load-sketches`) then export all-pairs
//!              estimated distances (CSV to stdout or --out file).
//!   query    — ingest then answer pair queries through the typed API.
//!   serve    — the serving surface. Without `--listen`: the concurrent
//!              stress demo (client threads drive pair batches through
//!              the query service *while* a writer streams more rows
//!              in). With `--listen <addr>`: a real TCP server speaking
//!              the wire protocol (see README), populated from a data
//!              source or `--load-sketches`.
//!   client   — drive a remote `serve --listen` server: `ping`,
//!              `stats`, `query a b [a b ...]`, `knn <id> <m>`.
//!   knn      — ingest then run k-NN through the typed API (top-k by
//!              stored id, served from the snapshot-rebuilt index; no
//!              raw-data index rebuild), with optional exact re-ranking.
//!   recover  — open a `--data-dir`, replay its WAL tail, seal the
//!              result into immutable segment files, print the
//!              recovery report (optionally export to `--out`).
//!   exp      — run a paper experiment (e1..e11) or `all`.
//!   platform — print the PJRT platform and artifact inventory.
//!   lint     — run pallas-lint ([`lpsketch::analysis`]) over the
//!              crate sources: the serving-path panic, codec
//!              allocation, and lock/epoch discipline gate.
//!
//! Global flags are [`lpsketch::config::Config`] keys (`--p 4 --k 128
//! --strategy basic --dist normal --pjrt ...`); see README.

use std::io::Write as _;
use std::sync::Arc;

use lpsketch::api::{self, Request, Response, TopKTarget};
use lpsketch::baselines::exact;
use lpsketch::config::Config;
use lpsketch::coordinator::{compactor, durable, persist, Compactor, Pipeline};
use lpsketch::data::{corpus, gen, io, RowMatrix};
use lpsketch::experiments;
use lpsketch::knn::{self, Neighbor};
use lpsketch::runtime::Engine;

fn usage() -> ! {
    eprintln!(
        "usage: lpsketch [--key value ...] <ingest|pairs|query|serve|client|knn|recover|exp|platform|lint> [args]\n\
         \n\
         data source: --data <file.bin|file.csv> | synthetic --data-dist --n --d | --data corpus\n\
         persistence: ingest --save-sketches <file.lpsk> (O(nk) state; the matrix can be discarded)\n\
                      pairs|serve --load-sketches <file.lpsk> (serve straight from saved sketches;\n\
                      pre-v3 files: --assume-projection + the original --seed/--dist re-enables\n\
                      fresh-vector queries)\n\
         durability:  --data-dir <dir> on ingest|serve (checksummed WAL + sealed segment files;\n\
                      an ingest ack means the batch is fsynced and survives a crash; an existing\n\
                      dir pins --p/--k/--seed/--dist/--strategy from its store.meta)\n\
         common keys: --p --k --strategy --dist --seed --workers --block-rows --mle --pjrt\n\
                      --compactor-interval-ms --io-retry-max\n\
         exp:         lpsketch exp <e1..e11|all> [--fast]\n\
         query:       lpsketch query <a> <b> [more pairs...]\n\
         serve:       lpsketch serve [clients] (in-process stress demo; --query-workers N)\n\
                      lpsketch serve --listen <addr> [--load-sketches f.lpsk | --data-dir d] (TCP)\n\
         client:      lpsketch client --connect <addr> <ping|stats|query a b ...|knn <id> <m>>\n\
         knn:         lpsketch knn <row-id> <m> [--rerank N]\n\
         recover:     lpsketch recover --data-dir <dir> [--out snap.lpsk] (replay WAL, seal\n\
                      segments, report; --out also exports a portable sketch file)\n\
         lint:        lpsketch lint [src-root] [--format json|sarif] (default rust/src; \
         findings on stdout, diagnostics on stderr, exits 1 on findings)"
    );
    std::process::exit(2);
}

fn load_data(cfg: &Config, source: Option<&str>) -> anyhow::Result<RowMatrix> {
    match source {
        Some("corpus") => Ok(corpus::generate(cfg.n, cfg.d, 80, cfg.seed).tf),
        Some(path) => io::load(std::path::Path::new(path)),
        None => Ok(gen::generate(cfg.data_dist, cfg.n, cfg.d, cfg.seed)),
    }
}

/// Restore a pipeline from a sketch file: shape and strategy from the
/// header, projection parameters too when the file records them (v3+).
/// Without recorded parameters the restore still serves every
/// stored-id query, but fresh-vector queries are disabled (loudly) —
/// unless `--assume-projection` asserts that the configured
/// `--seed`/`--dist` are the originals the file was sketched with.
fn restore_pipeline(
    mut cfg: Config,
    path: &std::path::Path,
    assume_projection: bool,
) -> anyhow::Result<Pipeline> {
    let header = persist::read_header(path)?;
    cfg.p = header.p as usize;
    cfg.k = header.k as usize;
    cfg.d = cfg.d.max(cfg.k);
    // The header records sidedness; restore the matching strategy so
    // query sketching pairs up correctly.
    cfg.strategy = if header.two_sided {
        lpsketch::projection::Strategy::Alternative
    } else {
        lpsketch::projection::Strategy::Basic
    };
    if let Some(info) = header.projection {
        cfg.seed = info.seed;
        cfg.dist = info.dist;
    }
    // Pre-v3 files don't record the projection; --assume-projection
    // lets the operator vouch for the configured --seed/--dist (which
    // were left untouched above) instead of losing fresh-vector
    // queries.
    let known = header.projection.is_some() || assume_projection;
    let (store, _) = persist::load(path, cfg.workers)?;
    cfg.n = store.len();
    println!(
        "config: {} (restored {} rows, {} segments{})",
        cfg.describe(),
        store.len(),
        store.segment_count(),
        if known {
            ""
        } else {
            "; projection unknown — fresh-vector queries disabled \
             (--assume-projection + the original --seed/--dist overrides)"
        }
    );
    Pipeline::with_store_restored(cfg, store, known)
}

/// Create-or-recover a durable data directory ([`durable::Durability`]).
///
/// An existing `store.meta` is authoritative: its shape (p, k, seed,
/// projection distribution, sidedness) is adopted into `cfg` so the
/// pipeline serves exactly what the directory holds — mismatched
/// command-line flags are overridden, not an error. A fresh directory
/// takes its shape from the configured flags. Prints the recovery
/// summary either way.
fn open_data_dir(cfg: &mut Config, root: &std::path::Path) -> anyhow::Result<durable::Opened> {
    let fs: Arc<dyn durable::DurableFs> = Arc::new(durable::RealFs);
    let dir = durable::DataDir::new(root);
    if let Some(disk) = durable::read_meta(fs.as_ref(), &dir)? {
        cfg.p = disk.p as usize;
        cfg.k = disk.k as usize;
        cfg.d = cfg.d.max(cfg.k);
        cfg.seed = disk.seed;
        cfg.dist = disk.dist;
        cfg.strategy = if disk.two_sided {
            lpsketch::projection::Strategy::Alternative
        } else {
            lpsketch::projection::Strategy::Basic
        };
    }
    let shape = durable::MetaShape::from_config(cfg);
    let opened = durable::Durability::open(fs, root, shape, cfg.workers)?;
    let r = &opened.report;
    if r.fresh {
        println!("data dir {}: fresh (created)", root.display());
    } else {
        println!(
            "data dir {}: recovered {} rows — snapshot {}, segments {} adopted / {} superseded, \
             wal {} file(s) / {} row(s) applied / {} skipped{}",
            root.display(),
            r.rows,
            r.snapshot_rows,
            r.segments_adopted,
            r.segments_superseded,
            r.wal_files,
            r.wal_rows_applied,
            r.wal_rows_skipped,
            if r.torn_tails > 0 {
                format!(", {} torn tail(s) dropped", r.torn_tails)
            } else {
                String::new()
            },
        );
    }
    Ok(opened)
}

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    let mut raw: Vec<String> = std::env::args().skip(1).collect();
    // Pull out the non-Config flags before Config sees them.
    let mut data_source: Option<String> = None;
    let mut out_path: Option<String> = None;
    let mut save_sketches: Option<String> = None;
    let mut load_sketches: Option<String> = None;
    let mut data_dir: Option<String> = None;
    let mut listen: Option<String> = None;
    let mut connect: Option<String> = None;
    let mut assume_projection = false;
    let mut fast = false;
    let mut lint_format: Option<String> = None;
    let mut rerank: usize = 0;
    let mut args = Vec::new();
    let mut it = raw.drain(..);
    let mut flag_err: Option<String> = None;
    while let Some(a) = it.next() {
        match a.as_str() {
            "--data" => data_source = it.next(),
            "--out" => out_path = it.next(),
            "--save-sketches" => save_sketches = it.next(),
            "--load-sketches" => load_sketches = it.next(),
            "--data-dir" => data_dir = it.next(),
            "--listen" => listen = it.next(),
            "--connect" => connect = it.next(),
            "--assume-projection" => assume_projection = true,
            "--fast" => fast = true,
            "--format" => lint_format = it.next(),
            "--rerank" => {
                // A bad value must error loudly, like every config key
                // (`--rerank abc` used to silently mean "no rerank").
                match it.next() {
                    Some(v) => match v.parse() {
                        Ok(n) => rerank = n,
                        Err(_) => {
                            flag_err = Some(format!("--rerank must be a number, got {v:?}"));
                            break;
                        }
                    },
                    None => {
                        flag_err = Some("--rerank needs a value".to_string());
                        break;
                    }
                }
            }
            _ => args.push(a),
        }
    }
    drop(it);
    if let Some(e) = flag_err {
        eprintln!("error: {e}");
        usage();
    }
    let positional = match cfg.apply_args(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}");
            usage();
        }
    };
    let Some(cmd) = positional.first() else { usage() };

    match cmd.as_str() {
        "lint" => {
            let root = positional
                .get(1)
                .map(std::path::PathBuf::from)
                .unwrap_or_else(|| std::path::PathBuf::from("rust/src"));
            anyhow::ensure!(
                root.is_dir(),
                "lint root {} is not a directory (run from the repo root, or pass one)",
                root.display()
            );
            let files = lpsketch::analysis::count_rs_files(&root)?;
            let findings = lpsketch::analysis::analyze_tree(&root)?;
            // Findings go to stdout (text lines, or one JSON/SARIF
            // document — empty arrays when clean); human diagnostics go
            // to stderr; the exit code is 1 exactly when findings > 0.
            match lint_format.as_deref() {
                Some("json") => print!("{}", lpsketch::analysis::to_json(&findings)),
                Some("sarif") => print!("{}", lpsketch::analysis::to_sarif(&findings)),
                Some(other) => {
                    anyhow::bail!("--format must be `json` or `sarif`, got {other:?}")
                }
                None => {
                    for f in &findings {
                        println!("{}", f.render());
                    }
                }
            }
            if findings.is_empty() {
                eprintln!("pallas-lint: {files} files clean");
            } else {
                eprintln!("pallas-lint: {} finding(s) across {files} files", findings.len());
                std::process::exit(1);
            }
        }
        "platform" => {
            let engine = Engine::start(&cfg.artifacts_dir)?;
            let h = engine.handle();
            println!("platform: {}", h.platform());
            println!("artifacts ({}):", h.manifest().artifacts.len());
            for a in &h.manifest().artifacts {
                println!(
                    "  {} op={} p={} b={} d={} k={}",
                    a.name,
                    a.op.as_str(),
                    a.p,
                    a.b,
                    a.d,
                    a.k
                );
            }
        }
        "ingest" => {
            let data = load_data(&cfg, data_source.as_deref())?;
            cfg.d = data.d();
            cfg.n = data.n();
            // With --data-dir, ingest is durable: every acknowledged
            // batch is in the fsynced WAL before `ingest` returns, and
            // the final pass seals the store into segment files so the
            // next start replays nothing.
            let pipeline = match &data_dir {
                Some(root) => {
                    let root = std::path::PathBuf::from(root);
                    let opened = open_data_dir(&mut cfg, &root)?;
                    println!("config: {}", cfg.describe());
                    let mut pipeline = Pipeline::with_store_restored(cfg, opened.store, true)?;
                    pipeline.attach_durability(Arc::new(opened.durability));
                    pipeline
                }
                None => {
                    println!("config: {}", cfg.describe());
                    Pipeline::new(cfg)?
                }
            };
            let report = pipeline.ingest(&data)?;
            println!(
                "ingested {} rows ({} blocks) in {:.3}s — {:.0} rows/s, pjrt rows: {}",
                report.rows,
                report.blocks,
                report.elapsed.as_secs_f64(),
                report.rows as f64 / report.elapsed.as_secs_f64(),
                report.pjrt_rows,
            );
            println!(
                "storage: data {} B → sketches {} B ({:.1}x compression)",
                report.data_bytes,
                report.sketch_bytes,
                report.data_bytes as f64 / report.sketch_bytes as f64
            );
            if pipeline.durability().is_some() {
                // Seal before exit: compact across the run's segments,
                // write them as immutable files, drop the covered WAL.
                compactor::run_pass(&pipeline);
                let m = pipeline.metrics();
                println!(
                    "durable: sealed {} segment file(s); wal tail holds {} record(s)",
                    m.segments_sealed, m.wal_records
                );
            }
            println!("metrics: {}", pipeline.metrics().render());
            if let Some(path) = &save_sketches {
                let cfg = pipeline.config();
                let header = persist::save(
                    pipeline.store(),
                    cfg.p,
                    Some(persist::ProjectionInfo { seed: cfg.seed, dist: cfg.dist }),
                    std::path::Path::new(path),
                )?;
                println!("saved {} sketch rows to {path} (p={} k={})", header.rows, header.p, header.k);
            }
        }
        "pairs" => {
            // With --load-sketches the saved O(nk) state serves the
            // export directly — no data matrix, no re-ingest (the
            // paper's storage claim as an operation).
            let pipeline = match &load_sketches {
                Some(path) => {
                    restore_pipeline(cfg, std::path::Path::new(path), assume_projection)?
                }
                None => {
                    let data = load_data(&cfg, data_source.as_deref())?;
                    cfg.d = data.d();
                    cfg.n = data.n();
                    println!("config: {}", cfg.describe());
                    let pipeline = Pipeline::new(cfg)?;
                    pipeline.ingest(&data)?;
                    pipeline
                }
            };
            let est = pipeline.all_pairs_condensed();
            let ids = pipeline.store().ids();
            let n = ids.len();
            let mut sink: Box<dyn std::io::Write> = match &out_path {
                Some(p) => Box::new(std::io::BufWriter::new(std::fs::File::create(p)?)),
                None => Box::new(std::io::BufWriter::new(std::io::stdout())),
            };
            writeln!(sink, "i,j,estimate")?;
            for i in 0..n {
                for j in (i + 1)..n {
                    writeln!(
                        sink,
                        "{},{},{}",
                        ids[i],
                        ids[j],
                        est[exact::condensed_index(n, i, j)]
                    )?;
                }
            }
            sink.flush()?;
            eprintln!("wrote {} pair estimates", est.len());
        }
        "query" => {
            let ids: Vec<u64> = positional[1..]
                .iter()
                .map(|s| s.parse().map_err(|_| anyhow::anyhow!("bad id {s:?}")))
                .collect::<anyhow::Result<_>>()?;
            anyhow::ensure!(
                !ids.is_empty() && ids.len() % 2 == 0,
                "query needs an even number of row ids"
            );
            let pairs: Vec<(u64, u64)> = ids.chunks(2).map(|c| (c[0], c[1])).collect();
            let data = load_data(&cfg, data_source.as_deref())?;
            cfg.d = data.d();
            cfg.n = data.n();
            let pipeline = Arc::new(Pipeline::new(cfg)?);
            pipeline.ingest(&data)?;
            // One typed request through the batched service — the same
            // surface a remote client hits over TCP.
            let service = pipeline.spawn_query_service();
            let ests = match service.call(Request::PairBatch(pairs.clone()))? {
                Response::PairBatch(ests) => ests,
                Response::Error(e) => anyhow::bail!("service error: {e}"),
                other => anyhow::bail!("unexpected response: {other:?}"),
            };
            for (&(a, b), est) in pairs.iter().zip(&ests) {
                match est {
                    Some(est) => {
                        let exact = exact::distance_f32(
                            data.row(a as usize),
                            data.row(b as usize),
                            pipeline.config().p,
                        );
                        println!(
                            "d({a},{b}): estimate={est:.6e} exact={exact:.6e} rel={:.4}",
                            (est - exact).abs() / exact.max(1e-300)
                        );
                    }
                    None => println!("d({a},{b}): unknown id"),
                }
            }
            println!("metrics: {}", pipeline.metrics().render());
        }
        "serve" if listen.is_some() => {
            // Real server mode: populate the store (ingest a data
            // source, or restore a sketch file — the paper's model of
            // serving from O(nk) state alone), then speak the wire
            // protocol until killed.
            let pipeline = Arc::new(match (&data_dir, &load_sketches) {
                (Some(root), _) => {
                    // Durable serving: recover the directory (sealed
                    // segments adopted, WAL tail replayed), then serve
                    // from it. Ingest-over-CLI runs write to the same
                    // directory; the background compactor below keeps
                    // sealing new state while the server runs.
                    let root = std::path::PathBuf::from(root);
                    let opened = open_data_dir(&mut cfg, &root)?;
                    cfg.n = opened.store.len();
                    println!("config: {}", cfg.describe());
                    let mut pipeline = Pipeline::with_store_restored(cfg, opened.store, true)?;
                    pipeline.attach_durability(Arc::new(opened.durability));
                    if let Some(src) = &data_source {
                        let data = load_data(pipeline.config(), Some(src.as_str()))?;
                        pipeline.ingest(&data)?;
                    }
                    pipeline
                }
                (None, Some(path)) => {
                    restore_pipeline(cfg, std::path::Path::new(path), assume_projection)?
                }
                (None, None) => {
                    let data = load_data(&cfg, data_source.as_deref())?;
                    cfg.d = data.d();
                    cfg.n = data.n();
                    println!("config: {}", cfg.describe());
                    let pipeline = Pipeline::new(cfg)?;
                    pipeline.ingest(&data)?;
                    pipeline
                }
            });
            // Background compactor: merges small segments across runs
            // and seals through the durability layer (no-op seal when
            // the store is not durable — skip the thread entirely).
            let _compactor = pipeline.durability().map(|_| {
                Compactor::spawn(
                    Arc::clone(&pipeline),
                    std::time::Duration::from_millis(pipeline.config().compactor_interval_ms),
                )
            });
            let service = pipeline.spawn_query_service();
            // Per-connection pacing (idle close + anti-slowloris stall
            // budget) with malformed-frame counting in `wire_errors`.
            let policy = api::ConnPolicy {
                wire_errors: pipeline.wire_errors_handle(),
                ..Default::default()
            };
            let server =
                api::Server::bind_with(listen.as_deref().expect("guarded"), service, policy)?;
            println!("listening on {}", server.local_addr()?);
            // Parent processes (tests, orchestrators) parse the line
            // above to learn the bound port — get it out before the
            // blocking accept loop.
            std::io::stdout().flush()?;
            server.run()?;
        }
        "serve" => {
            // Ingest-during-serve stress demo: populate the store,
            // start the query service, then answer pair batches from
            // `clients` threads while a writer concurrently streams the
            // same matrix in again (fresh ids). Snapshot serving means
            // the writer never waits on a scan and every answer comes
            // from one consistent epoch.
            let clients: usize = positional
                .get(1)
                .map(|s| s.parse())
                .transpose()
                .map_err(|_| anyhow::anyhow!("serve [clients] must be a number"))?
                .unwrap_or(4)
                .max(1);
            let data = load_data(&cfg, data_source.as_deref())?;
            cfg.d = data.d();
            cfg.n = data.n();
            println!("config: {}", cfg.describe());
            let pipeline = Arc::new(Pipeline::new(cfg)?);
            pipeline.ingest(&data)?;
            let service = pipeline.spawn_query_service();
            let n0 = pipeline.rows() as u64;
            let queries_per_client = 500u64;
            let t0 = std::time::Instant::now();
            let served = std::sync::atomic::AtomicU64::new(0);
            std::thread::scope(|s| -> anyhow::Result<()> {
                let writer = {
                    let pipeline = Arc::clone(&pipeline);
                    s.spawn(move || pipeline.ingest(&data))
                };
                let mut readers = Vec::new();
                for t in 0..clients as u64 {
                    let service = service.clone();
                    let served = &served;
                    readers.push(s.spawn(move || -> anyhow::Result<()> {
                        for i in 0..queries_per_client {
                            let a = (t * 131 + i * 7) % n0;
                            let b = (t * 17 + i * 13 + 1) % n0;
                            if service.query(a, b)?.is_some() {
                                served.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            }
                        }
                        Ok(())
                    }));
                }
                for r in readers {
                    r.join().expect("client thread panicked")?;
                }
                writer.join().expect("writer thread panicked")?;
                Ok(())
            })?;
            let secs = t0.elapsed().as_secs_f64();
            let served = served.load(std::sync::atomic::Ordering::Relaxed);
            println!(
                "served {served} pair queries from {clients} clients in {secs:.3}s \
                 ({:.0} q/s) while ingesting {} rows concurrently",
                served as f64 / secs,
                pipeline.rows() as u64 - n0,
            );
            println!("metrics: {}", pipeline.metrics().render());
        }
        "client" => {
            let addr = connect
                .ok_or_else(|| anyhow::anyhow!("client needs --connect <addr>"))?;
            let mut client = api::Client::connect(addr.as_str())?;
            let action = positional.get(1).map(|s| s.as_str()).unwrap_or("ping");
            match action {
                "ping" => println!("pong (protocol v{})", client.ping()?),
                "stats" => {
                    let s = client.stats()?;
                    println!(
                        "rows={} map_rows={} segments={} epoch={} p={} k={} two_sided={} \
                         projection_known={}",
                        s.rows, s.map_rows, s.segments, s.epoch, s.p, s.k, s.two_sided,
                        s.projection_known,
                    );
                    println!(
                        "served={} ingested={} batches={} compactions={} in_flight={} \
                         snapshot_age={}",
                        s.queries_served,
                        s.rows_ingested,
                        s.batches_flushed,
                        s.compactions,
                        s.queries_in_flight,
                        s.snapshot_age,
                    );
                }
                "query" => {
                    let ids: Vec<u64> = positional[2..]
                        .iter()
                        .map(|s| s.parse().map_err(|_| anyhow::anyhow!("bad id {s:?}")))
                        .collect::<anyhow::Result<_>>()?;
                    anyhow::ensure!(
                        !ids.is_empty() && ids.len() % 2 == 0,
                        "client query needs an even number of row ids"
                    );
                    let pairs: Vec<(u64, u64)> = ids.chunks(2).map(|c| (c[0], c[1])).collect();
                    for (&(a, b), est) in pairs.iter().zip(client.pairs(&pairs)?.iter()) {
                        match est {
                            Some(est) => println!("d({a},{b}): estimate={est:.6e}"),
                            None => println!("d({a},{b}): unknown id"),
                        }
                    }
                }
                "knn" => {
                    anyhow::ensure!(positional.len() >= 4, "client knn needs <id> <m>");
                    let id: u64 = positional[2].parse()?;
                    let m: u32 = positional[3].parse()?;
                    let list = client.top_k_id(id, m)?;
                    println!("top-{m} for stored row {id}:");
                    for (nid, d) in list {
                        println!("  row {nid:>6}  d̂={d:.6e}");
                    }
                }
                other => {
                    eprintln!("unknown client action {other:?}");
                    usage();
                }
            }
        }
        "knn" => {
            anyhow::ensure!(positional.len() >= 3, "knn needs <row-id> <m>");
            let qid: u64 = positional[1].parse()?;
            let m: usize = positional[2].parse()?;
            let data = load_data(&cfg, data_source.as_deref())?;
            cfg.d = data.d();
            cfg.n = data.n();
            let pipeline = Arc::new(Pipeline::new(cfg)?);
            pipeline.ingest(&data)?;
            let p = pipeline.config().p;
            // Top-k through the typed API: the stored row's sketch is
            // the query, served from the snapshot-rebuilt index — the
            // raw matrix is only consulted for exact re-ranking and the
            // recall report below.
            let service = pipeline.spawn_query_service();
            let fetch = m.max(rerank) as u32;
            let target = TopKTarget::StoredId(qid);
            let cands = match service.call(Request::TopK { target, top: fetch })? {
                Response::TopK(cands) => cands,
                Response::Error(e) => anyhow::bail!("service error: {e}"),
                other => anyhow::bail!("unexpected response: {other:?}"),
            };
            let got: Vec<Neighbor> = if rerank > 0 {
                // Exact re-rank of the sketch candidates (two-phase
                // search; the candidate list came from the API).
                let q = data.row(qid as usize);
                let mut scored: Vec<Neighbor> = cands
                    .iter()
                    .map(|&(id, _)| Neighbor {
                        index: id as usize,
                        distance: exact::distance_f32(q, data.row(id as usize), p),
                        exact: true,
                    })
                    .collect();
                scored.sort_by(|a, b| {
                    a.distance.total_cmp(&b.distance).then(a.index.cmp(&b.index))
                });
                scored.truncate(m);
                scored
            } else {
                cands
                    .iter()
                    .take(m)
                    .map(|&(id, distance)| Neighbor { index: id as usize, distance, exact: false })
                    .collect()
            };
            let truth = knn::exact_knn(&data, data.row(qid as usize), m, p);
            println!(
                "top-{m} for row {qid} (recall {:.2}):",
                knn::recall(&got, &truth)
            );
            for nb in got {
                println!(
                    "  row {:>6}  d̂={:.6e}{}",
                    nb.index,
                    nb.distance,
                    if nb.exact { " (exact)" } else { "" }
                );
            }
        }
        "recover" => {
            // Offline recovery: replay the directory, seal everything
            // into immutable segment files (so the next `serve` start
            // adopts segments and replays nothing), report what was
            // found. `--out` additionally exports a portable sketch
            // file, projection parameters included.
            let root = match data_dir.as_deref().or(positional.get(1).map(|s| s.as_str())) {
                Some(r) => std::path::PathBuf::from(r),
                None => {
                    eprintln!("error: recover needs --data-dir <dir> (or a positional dir)");
                    usage();
                }
            };
            {
                let fs = durable::RealFs;
                let dir = durable::DataDir::new(&root);
                anyhow::ensure!(
                    durable::read_meta(&fs, &dir)?.is_some(),
                    "{} has no store.meta — nothing to recover",
                    root.display()
                );
            }
            let opened = open_data_dir(&mut cfg, &root)?;
            let shape = *opened.durability.shape();
            let sealed = opened.durability.seal(&opened.store)?;
            println!(
                "sealed: {} segment file(s) written, {} superseded file(s) removed, \
                 {} wal file(s) retired",
                sealed.segments_written, sealed.seg_files_removed, sealed.wal_files_removed
            );
            println!(
                "store: {} rows, p={} k={} two_sided={} — ready to serve \
                 (lpsketch serve --listen <addr> --data-dir {})",
                opened.store.len(),
                shape.p,
                shape.k,
                shape.two_sided,
                root.display()
            );
            if let Some(out) = &out_path {
                let header = persist::save(
                    &opened.store,
                    shape.p as usize,
                    Some(shape.projection_info()),
                    std::path::Path::new(out),
                )?;
                println!("exported {} rows to {out} (p={} k={})", header.rows, header.p, header.k);
            }
        }
        "exp" => {
            let id = positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            if id == "all" {
                let results = experiments::run_all(fast);
                let failed: Vec<_> =
                    results.iter().filter(|(_, ok)| !ok).map(|(id, _)| id.clone()).collect();
                anyhow::ensure!(failed.is_empty(), "experiments failed: {failed:?}");
            } else {
                let acc = experiments::run(id, fast)?;
                let ok = experiments::common::report(&acc);
                anyhow::ensure!(ok, "experiment {id} failed");
            }
        }
        other => {
            eprintln!("unknown command {other:?}");
            usage();
        }
    }
    Ok(())
}
