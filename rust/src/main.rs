//! `lpsketch` — CLI for the sketch-based even-p l_p distance pipeline.
//!
//! Subcommands:
//!   ingest   — stream a matrix (file or synthetic) into sketches, report
//!              the scan/storage accounting.
//!   pairs    — ingest then export all-pairs estimated distances (CSV to
//!              stdout or --out file).
//!   query    — ingest then answer pair queries from the command line.
//!   serve    — concurrent-serving demo: answer pair batches through the
//!              query service *while* a writer streams more rows in
//!              (epoch snapshots keep readers and writers out of each
//!              other's way).
//!   knn      — ingest then run k-NN queries with optional re-ranking.
//!   exp      — run a paper experiment (e1..e11) or `all`.
//!   platform — print the PJRT platform and artifact inventory.
//!
//! Global flags are [`lpsketch::config::Config`] keys (`--p 4 --k 128
//! --strategy basic --dist normal --pjrt ...`); see README.

use std::io::Write as _;
use std::sync::Arc;

use lpsketch::baselines::exact;
use lpsketch::config::Config;
use lpsketch::coordinator::Pipeline;
use lpsketch::data::{corpus, gen, io, RowMatrix};
use lpsketch::experiments;
use lpsketch::knn::KnnIndex;
use lpsketch::runtime::Engine;

fn usage() -> ! {
    eprintln!(
        "usage: lpsketch [--key value ...] <ingest|pairs|query|serve|knn|exp|platform> [args]\n\
         \n\
         data source: --data <file.bin|file.csv> | synthetic --data-dist --n --d | --data corpus\n\
         persistence: ingest --save-sketches <file.lpsk> (O(nk) state; the matrix can be discarded)\n\
                      pairs --load-sketches <file.lpsk> (serve straight from saved sketches)\n\
         common keys: --p --k --strategy --dist --seed --workers --block-rows --mle --pjrt\n\
         exp:         lpsketch exp <e1..e11|all> [--fast]\n\
         query:       lpsketch query <a> <b> [more pairs...]\n\
         serve:       lpsketch serve [clients] (default 4; --query-workers N sizes the service)\n\
         knn:         lpsketch knn <row-id> <m> [--rerank N]"
    );
    std::process::exit(2);
}

fn load_data(cfg: &Config, source: Option<&str>) -> anyhow::Result<RowMatrix> {
    match source {
        Some("corpus") => Ok(corpus::generate(cfg.n, cfg.d, 80, cfg.seed).tf),
        Some(path) => io::load(std::path::Path::new(path)),
        None => Ok(gen::generate(cfg.data_dist, cfg.n, cfg.d, cfg.seed)),
    }
}

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    let mut raw: Vec<String> = std::env::args().skip(1).collect();
    // Pull out --data/--out/--fast/--rerank before Config sees them.
    let mut data_source: Option<String> = None;
    let mut out_path: Option<String> = None;
    let mut save_sketches: Option<String> = None;
    let mut load_sketches: Option<String> = None;
    let mut fast = false;
    let mut rerank: usize = 0;
    let mut args = Vec::new();
    let mut it = raw.drain(..);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--data" => data_source = it.next(),
            "--out" => out_path = it.next(),
            "--save-sketches" => save_sketches = it.next(),
            "--load-sketches" => load_sketches = it.next(),
            "--fast" => fast = true,
            "--rerank" => rerank = it.next().and_then(|v| v.parse().ok()).unwrap_or(0),
            _ => args.push(a),
        }
    }
    let positional = match cfg.apply_args(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}");
            usage();
        }
    };
    let Some(cmd) = positional.first() else { usage() };

    match cmd.as_str() {
        "platform" => {
            let engine = Engine::start(&cfg.artifacts_dir)?;
            let h = engine.handle();
            println!("platform: {}", h.platform());
            println!("artifacts ({}):", h.manifest().artifacts.len());
            for a in &h.manifest().artifacts {
                println!(
                    "  {} op={} p={} b={} d={} k={}",
                    a.name,
                    a.op.as_str(),
                    a.p,
                    a.b,
                    a.d,
                    a.k
                );
            }
        }
        "ingest" => {
            let data = load_data(&cfg, data_source.as_deref())?;
            cfg.d = data.d();
            cfg.n = data.n();
            println!("config: {}", cfg.describe());
            let pipeline = Pipeline::new(cfg)?;
            let report = pipeline.ingest(&data)?;
            println!(
                "ingested {} rows ({} blocks) in {:.3}s — {:.0} rows/s, pjrt rows: {}",
                report.rows,
                report.blocks,
                report.elapsed.as_secs_f64(),
                report.rows as f64 / report.elapsed.as_secs_f64(),
                report.pjrt_rows,
            );
            println!(
                "storage: data {} B → sketches {} B ({:.1}x compression)",
                report.data_bytes,
                report.sketch_bytes,
                report.data_bytes as f64 / report.sketch_bytes as f64
            );
            println!("metrics: {}", pipeline.metrics().render());
            if let Some(path) = &save_sketches {
                let header = lpsketch::coordinator::persist::save(
                    pipeline.store(),
                    pipeline.config().p,
                    std::path::Path::new(path),
                )?;
                println!("saved {} sketch rows to {path} (p={} k={})", header.rows, header.p, header.k);
            }
        }
        "pairs" => {
            // With --load-sketches the saved O(nk) state serves the
            // export directly — no data matrix, no re-ingest (the
            // paper's storage claim as an operation).
            let pipeline = match &load_sketches {
                Some(path) => {
                    let path = std::path::Path::new(path);
                    let header = lpsketch::coordinator::persist::read_header(path)?;
                    cfg.p = header.p as usize;
                    cfg.k = header.k as usize;
                    cfg.d = cfg.d.max(cfg.k);
                    // The header records sidedness; restore the matching
                    // strategy so query sketching pairs up correctly.
                    cfg.strategy = if header.two_sided {
                        lpsketch::projection::Strategy::Alternative
                    } else {
                        lpsketch::projection::Strategy::Basic
                    };
                    let (store, _) =
                        lpsketch::coordinator::persist::load(path, cfg.workers)?;
                    cfg.n = store.len();
                    println!(
                        "config: {} (restored {} rows, {} segments)",
                        cfg.describe(),
                        store.len(),
                        store.segment_count()
                    );
                    Pipeline::with_store(cfg, store)?
                }
                None => {
                    let data = load_data(&cfg, data_source.as_deref())?;
                    cfg.d = data.d();
                    cfg.n = data.n();
                    println!("config: {}", cfg.describe());
                    let pipeline = Pipeline::new(cfg)?;
                    pipeline.ingest(&data)?;
                    pipeline
                }
            };
            let est = pipeline.all_pairs_condensed();
            let ids = pipeline.store().ids();
            let n = ids.len();
            let mut sink: Box<dyn std::io::Write> = match &out_path {
                Some(p) => Box::new(std::io::BufWriter::new(std::fs::File::create(p)?)),
                None => Box::new(std::io::BufWriter::new(std::io::stdout())),
            };
            writeln!(sink, "i,j,estimate")?;
            for i in 0..n {
                for j in (i + 1)..n {
                    writeln!(
                        sink,
                        "{},{},{}",
                        ids[i],
                        ids[j],
                        est[exact::condensed_index(n, i, j)]
                    )?;
                }
            }
            sink.flush()?;
            eprintln!("wrote {} pair estimates", est.len());
        }
        "query" => {
            let pairs: Vec<u64> = positional[1..]
                .iter()
                .map(|s| s.parse().map_err(|_| anyhow::anyhow!("bad id {s:?}")))
                .collect::<anyhow::Result<_>>()?;
            anyhow::ensure!(
                !pairs.is_empty() && pairs.len() % 2 == 0,
                "query needs an even number of row ids"
            );
            let data = load_data(&cfg, data_source.as_deref())?;
            cfg.d = data.d();
            cfg.n = data.n();
            let pipeline = Arc::new(Pipeline::new(cfg)?);
            pipeline.ingest(&data)?;
            let service = pipeline.spawn_query_service();
            for pair in pairs.chunks(2) {
                let (a, b) = (pair[0], pair[1]);
                match service.query(a, b)? {
                    Some(est) => {
                        let exact = exact::distance_f32(
                            data.row(a as usize),
                            data.row(b as usize),
                            pipeline.config().p,
                        );
                        println!(
                            "d({a},{b}): estimate={est:.6e} exact={exact:.6e} rel={:.4}",
                            (est - exact).abs() / exact.max(1e-300)
                        );
                    }
                    None => println!("d({a},{b}): unknown id"),
                }
            }
            println!("metrics: {}", pipeline.metrics().render());
        }
        "serve" => {
            // Ingest-during-serve demo: populate the store, start the
            // query service, then answer pair batches from `clients`
            // threads while a writer concurrently streams the same
            // matrix in again (fresh ids). Snapshot serving means the
            // writer never waits on a scan and every answer comes from
            // one consistent epoch.
            let clients: usize = positional
                .get(1)
                .map(|s| s.parse())
                .transpose()
                .map_err(|_| anyhow::anyhow!("serve [clients] must be a number"))?
                .unwrap_or(4)
                .max(1);
            let data = load_data(&cfg, data_source.as_deref())?;
            cfg.d = data.d();
            cfg.n = data.n();
            println!("config: {} query_workers={}", cfg.describe(), cfg.query_workers);
            let pipeline = Arc::new(Pipeline::new(cfg)?);
            pipeline.ingest(&data)?;
            let service = pipeline.spawn_query_service();
            let n0 = pipeline.rows() as u64;
            let queries_per_client = 500u64;
            let t0 = std::time::Instant::now();
            let served = std::sync::atomic::AtomicU64::new(0);
            std::thread::scope(|s| -> anyhow::Result<()> {
                let writer = {
                    let pipeline = Arc::clone(&pipeline);
                    s.spawn(move || pipeline.ingest(&data))
                };
                let mut readers = Vec::new();
                for t in 0..clients as u64 {
                    let service = service.clone();
                    let served = &served;
                    readers.push(s.spawn(move || -> anyhow::Result<()> {
                        for i in 0..queries_per_client {
                            let a = (t * 131 + i * 7) % n0;
                            let b = (t * 17 + i * 13 + 1) % n0;
                            if service.query(a, b)?.is_some() {
                                served.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            }
                        }
                        Ok(())
                    }));
                }
                for r in readers {
                    r.join().expect("client thread panicked")?;
                }
                writer.join().expect("writer thread panicked")?;
                Ok(())
            })?;
            let secs = t0.elapsed().as_secs_f64();
            let served = served.load(std::sync::atomic::Ordering::Relaxed);
            println!(
                "served {served} pair queries from {clients} clients in {secs:.3}s \
                 ({:.0} q/s) while ingesting {} rows concurrently",
                served as f64 / secs,
                pipeline.rows() as u64 - n0,
            );
            println!("metrics: {}", pipeline.metrics().render());
        }
        "knn" => {
            anyhow::ensure!(positional.len() >= 3, "knn needs <row-id> <m>");
            let qid: usize = positional[1].parse()?;
            let m: usize = positional[2].parse()?;
            let data = load_data(&cfg, data_source.as_deref())?;
            let index = KnnIndex::build(&data, cfg.projection_spec(), cfg.p)?;
            let q = data.row(qid).to_vec();
            let got = if rerank > 0 {
                index.query_rerank(&data, &q, m, rerank)
            } else {
                index.query(&q, m)
            };
            let truth = lpsketch::knn::exact_knn(&data, &q, m, cfg.p);
            println!(
                "top-{m} for row {qid} (recall {:.2}):",
                lpsketch::knn::recall(&got, &truth)
            );
            for nb in got {
                println!(
                    "  row {:>6}  d̂={:.6e}{}",
                    nb.index,
                    nb.distance,
                    if nb.exact { " (exact)" } else { "" }
                );
            }
        }
        "exp" => {
            let id = positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            if id == "all" {
                let results = experiments::run_all(fast);
                let failed: Vec<_> =
                    results.iter().filter(|(_, ok)| !ok).map(|(id, _)| id.clone()).collect();
                anyhow::ensure!(failed.is_empty(), "experiments failed: {failed:?}");
            } else {
                let acc = experiments::run(id, fast)?;
                let ok = experiments::common::report(&acc);
                anyhow::ensure!(ok, "experiment {id} failed");
            }
        }
        other => {
            eprintln!("unknown command {other:?}");
            usage();
        }
    }
    Ok(())
}
