//! Register-tiled GEMM kernels for the sketch ingest hot path.
//!
//! The projection step (x^∘m)ᵀR (§2.1–2.2) is exactly a matmul of the
//! power-expanded data block against R. The per-row reference path in
//! [`super::sketcher`] walks it as a feature-outer axpy loop — one
//! Hadamard ladder *per order touch* and one k-wide read-modify-write
//! per (entry, order). This module restructures it GEMM-style:
//!
//! 1. [`expand_powers`] walks the ladder **once per data entry** (in
//!    f64, so the marginal moments keep full precision) and lays the
//!    f32 sketch powers out as an order-major `(orders·rows) × chunk`
//!    matrix — `P_m` is a contiguous `rows × chunk` row-major panel.
//! 2. [`gemm`] drives `C_m += P_m · R_chunk` through a 4-row × 8-lane
//!    register micro-kernel ([`MR`]×[`NR`]) with the depth dimension
//!    tiled by [`KC`]: the 4×8 accumulator block lives entirely in
//!    registers across a depth tile, each `R` row is loaded once per
//!    4 data rows, and each power is loaded once per 8 sketch lanes —
//!    versus 2 loads + 1 store per FMA in the axpy formulation.
//! 3. [`gemm_sparse`] is the CSR variant for sparse three-point
//!    distributions: `R` nonzeros are walked row-by-row (the paper's §4
//!    sparsity speedup), with the precomputed powers replacing the
//!    per-order ladder recomputation.
//!
//! ## Loop order and determinism
//!
//! `gemm` nests depth-tile → lane-tile → row-strip, so the `R` panel of
//! one (depth, lane) tile (≤ [`KC`]·[`NR`] floats ≈ 16 KiB) stays L1-
//! resident while every row strip streams past it. For any output slot
//! `(row, lane)` the accumulation sequence is: partial products in
//! ascending feature order within a depth tile (in the register
//! accumulator), tiles flushed to `C` in ascending depth order. That
//! sequence depends only on the slot — not on which rows share a strip
//! or lanes share a tile — so results are **bitwise independent of row
//! banding**, which is what makes the worker-sharded block sketcher
//! deterministic in its worker count.

use super::matrix::ProjectionMatrix;

/// Micro-kernel rows (register-blocked data rows per strip).
pub const MR: usize = 4;
/// Micro-kernel lanes (register-blocked sketch columns per tile).
pub const NR: usize = 8;
/// Depth (feature) tile: bounds the L1-resident `R` panel at
/// `KC × NR` f32s and keeps the register accumulators hot across it.
pub const KC: usize = 512;

/// One entry's Hadamard ladder step, shared by every CPU sketch path
/// (per-row reference, GEMM expansion, sparse-data axpy) so the f64
/// moment / f32 sketch-power semantics can never diverge between the
/// oracle and the tiled kernels: walk x, x², …, x^nm in f64, add each
/// rung to the entry's moment row (`mrow`, length nm), and record the
/// f32 casts of the first `orders` rungs in `pw`.
///
/// Callers are responsible for the `x == 0.0` skip (zero entries
/// contribute nothing and each path handles the powers output shape
/// differently).
#[inline]
pub(crate) fn power_ladder_update(x: f32, orders: usize, mrow: &mut [f64], pw: &mut [f32]) {
    let xf = x as f64;
    let mut ladder = 1.0f64;
    for (m, slot) in mrow.iter_mut().enumerate() {
        ladder *= xf;
        if m < orders {
            pw[m] = ladder as f32;
        }
        *slot += ladder;
    }
}

/// Expand one D-chunk of every row into the order-major powers matrix
/// and fold the chunk into the marginal moments.
///
/// * `powers[((m-1)·rows + r)·cl + t]` ← `x_r[start+t]^m` (f32) for
///   m = 1..=orders — each `P_m` a contiguous `rows × cl` panel.
/// * `moments[r·nm + (m-1)]` += `x_r[start+t]^m` (f64) for m = 1..=nm.
///
/// The ladder runs once per entry in f64: sketch powers are the f32
/// casts of its rungs, while the high-order moments feeding the MLE
/// cubic (`core::mle`) accumulate at full precision — an f32 ladder
/// visibly loses digits by order 2(p−1) once |x| strays far from 1.
pub fn expand_powers(
    rows: &[&[f32]],
    start: usize,
    cl: usize,
    orders: usize,
    nm: usize,
    powers: &mut [f32],
    moments: &mut [f64],
) {
    let n = rows.len();
    debug_assert!(powers.len() >= orders * n * cl);
    debug_assert!(moments.len() >= n * nm);
    debug_assert!(nm >= orders);
    for (r, row) in rows.iter().enumerate() {
        let mrow = &mut moments[r * nm..(r + 1) * nm];
        // SIMD-dispatched per row; bitwise-identical to the scalar
        // ladder (see `projection::simd` module docs).
        super::simd::expand_row(&row[start..start + cl], r, n, cl, orders, nm, powers, mrow);
    }
}

/// `C += A · B`: C is `m × n` row-major, A `m × depth` row-major, B
/// `depth × n` row-major. Register-tiled (see module docs); handles
/// ragged edges (`m % MR != 0`, `n % NR != 0`) through an edge kernel
/// with the identical per-slot accumulation sequence.
pub fn gemm(c: &mut [f32], a: &[f32], b: &[f32], m: usize, depth: usize, n: usize) {
    debug_assert_eq!(c.len(), m * n);
    debug_assert_eq!(a.len(), m * depth);
    debug_assert_eq!(b.len(), depth * n);
    let mut t0 = 0;
    while t0 < depth {
        let tc = KC.min(depth - t0);
        let mut j0 = 0;
        while j0 < n {
            let jc = NR.min(n - j0);
            let mut i0 = 0;
            while i0 < m {
                let ic = MR.min(m - i0);
                if ic == MR && jc == NR {
                    kernel_full(c, a, b, i0, j0, t0, tc, depth, n);
                } else {
                    kernel_edge(c, a, b, i0, ic, j0, jc, t0, tc, depth, n);
                }
                i0 += MR;
            }
            j0 += NR;
        }
        t0 += KC;
    }
}

/// Full MR×NR micro-kernel: 32 f32 accumulators in registers across the
/// depth tile, one B row load per 4 data rows.
#[allow(clippy::too_many_arguments)]
#[inline]
fn kernel_full(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    i0: usize,
    j0: usize,
    t0: usize,
    tc: usize,
    depth: usize,
    n: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    let a0 = &a[i0 * depth + t0..][..tc];
    let a1 = &a[(i0 + 1) * depth + t0..][..tc];
    let a2 = &a[(i0 + 2) * depth + t0..][..tc];
    let a3 = &a[(i0 + 3) * depth + t0..][..tc];
    // SIMD-dispatched register tile; every path reproduces the scalar
    // per-slot accumulation order bitwise (`projection::simd`).
    super::simd::gemm_tile_4x8(&mut acc, [a0, a1, a2, a3], b, t0, tc, n, j0);
    for (i, acc_row) in acc.iter().enumerate() {
        let crow = &mut c[(i0 + i) * n + j0..][..NR];
        for j in 0..NR {
            crow[j] += acc_row[j];
        }
    }
}

/// Ragged-edge kernel (`ic ≤ MR` rows, `jc ≤ NR` lanes). Same per-slot
/// accumulation sequence as [`kernel_full`] so tiling stays bitwise
/// consistent across shapes.
#[allow(clippy::too_many_arguments)]
#[inline]
fn kernel_edge(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    i0: usize,
    ic: usize,
    j0: usize,
    jc: usize,
    t0: usize,
    tc: usize,
    depth: usize,
    n: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for t in 0..tc {
        let bt = &b[(t0 + t) * n + j0..][..jc];
        for i in 0..ic {
            let x = a[(i0 + i) * depth + t0 + t];
            for j in 0..jc {
                acc[i][j] += x * bt[j];
            }
        }
    }
    for i in 0..ic {
        let crow = &mut c[(i0 + i) * n + j0..][..jc];
        for j in 0..jc {
            crow[j] += acc[i][j];
        }
    }
}

/// CSR-like nonzero list of a materialized R chunk — built once per
/// chunk, shared across every row in the batch (the sparse three-point
/// distributions are 1−1/s zeros; touching only nonzeros is the paper's
/// §4 "sparsity speedup").
#[derive(Debug)]
pub(crate) struct SparseChunk {
    row0: usize,
    /// Prefix offsets, len rows+1.
    offsets: Vec<u32>,
    /// (column, value) pairs of nonzeros, row-major.
    nnz: Vec<(u32, f32)>,
}

impl SparseChunk {
    pub(crate) fn from_dense(mat: &ProjectionMatrix) -> Self {
        let mut offsets = Vec::with_capacity(mat.rows + 1);
        let mut nnz = Vec::new();
        offsets.push(0u32);
        for i in 0..mat.rows {
            let row = &mat.data[i * mat.k..(i + 1) * mat.k];
            for (j, &r) in row.iter().enumerate() {
                if r != 0.0 {
                    nnz.push((j as u32, r));
                }
            }
            offsets.push(nnz.len() as u32);
        }
        SparseChunk { row0: mat.row0, offsets, nnz }
    }

    /// Nonzeros of absolute feature row `i` (offset by the chunk start).
    #[inline]
    pub(crate) fn row(&self, i: usize) -> &[(u32, f32)] {
        let r = i - self.row0;
        &self.nnz[self.offsets[r] as usize..self.offsets[r + 1] as usize]
    }
}

/// Sparse counterpart of [`gemm`]: `C += P · R` where `R`'s chunk rows
/// `[start, start+cl)` are given as CSR nonzeros. `P` is the `rows × cl`
/// powers panel of one order; rows with an underflowed (exactly zero)
/// power skip the R row entirely.
pub(crate) fn gemm_sparse(
    c: &mut [f32],
    a: &[f32],
    sp: &SparseChunk,
    start: usize,
    rows: usize,
    cl: usize,
    k: usize,
) {
    debug_assert_eq!(c.len(), rows * k);
    debug_assert_eq!(a.len(), rows * cl);
    for r in 0..rows {
        let arow = &a[r * cl..(r + 1) * cl];
        let crow = &mut c[r * k..(r + 1) * k];
        for (t, &pw) in arow.iter().enumerate() {
            if pw == 0.0 {
                continue;
            }
            for &(j, v) in sp.row(start + t) {
                crow[j as usize] += pw * v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Naive triple loop C += A·B in the exact per-slot order the tiled
    /// kernel uses within one depth tile (t ascending).
    fn naive_gemm(c: &mut [f32], a: &[f32], b: &[f32], m: usize, depth: usize, n: usize) {
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for t in 0..depth {
                    acc += (a[i * depth + t] as f64) * (b[t * n + j] as f64);
                }
                c[i * n + j] += acc as f32;
            }
        }
    }

    fn pattern(len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|i| ((i * 37 % 23) as f32 - 11.0) * scale).collect()
    }

    #[test]
    fn tiled_matches_naive_over_ragged_shapes() {
        // Shapes straddle every tile edge: m % MR, n % NR, depth % KC.
        for &(m, depth, n) in &[
            (1usize, 1usize, 1usize),
            (4, 16, 8),
            (5, 17, 9),
            (3, 600, 7),
            (8, 513, 16),
            (13, 1025, 12),
        ] {
            let a = pattern(m * depth, 0.01);
            let b = pattern(depth * n, 0.02);
            let mut c = pattern(m * n, 0.5);
            let mut want = c.clone();
            gemm(&mut c, &a, &b, m, depth, n);
            naive_gemm(&mut want, &a, &b, m, depth, n);
            for (i, (&g, &w)) in c.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-3 * (1.0 + w.abs()),
                    "shape ({m},{depth},{n}) slot {i}: {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn expand_powers_walks_the_ladder_once() {
        let r0: Vec<f32> = vec![0.5, -2.0, 0.0, 3.0];
        let r1: Vec<f32> = vec![1.5, 0.25, -1.0, 0.0];
        let rows: Vec<&[f32]> = vec![&r0, &r1];
        let (orders, nm, cl) = (3usize, 6usize, 4usize);
        let mut powers = vec![f32::NAN; orders * 2 * cl];
        let mut moments = vec![0.0f64; 2 * nm];
        expand_powers(&rows, 0, cl, orders, nm, &mut powers, &mut moments);
        for (r, row) in rows.iter().enumerate() {
            for m in 1..=orders {
                for (t, &x) in row.iter().enumerate() {
                    let want = (x as f64).powi(m as i32) as f32;
                    let got = powers[((m - 1) * 2 + r) * cl + t];
                    assert!((got - want).abs() <= 1e-6 * (1.0 + want.abs()), "r={r} m={m} t={t}");
                }
            }
            for m in 1..=nm {
                let want: f64 = row.iter().map(|&x| (x as f64).powi(m as i32)).sum();
                let got = moments[r * nm + (m - 1)];
                assert!((got - want).abs() <= 1e-9 * (1.0 + want.abs()), "moment r={r} m={m}");
            }
        }
    }

    #[test]
    fn expand_powers_overwrites_reused_buffer() {
        // Buffer reuse across chunks must not leak stale values through
        // the zero-entry skip path.
        let row: Vec<f32> = vec![0.0, 0.0];
        let rows: Vec<&[f32]> = vec![&row];
        let mut powers = vec![7.0f32; 2 * 2];
        let mut moments = vec![0.0f64; 4];
        expand_powers(&rows, 0, 2, 2, 4, &mut powers, &mut moments);
        assert!(powers.iter().all(|&p| p == 0.0));
        assert!(moments.iter().all(|&m| m == 0.0));
    }

    #[test]
    fn dispatched_gemm_is_bitwise_scalar_over_ragged_shapes() {
        use crate::projection::simd;
        let _g = simd::lock_dispatch();
        for &(m, depth, n) in &[
            (1usize, 1usize, 1usize),
            (4, 16, 8),
            (5, 17, 9),
            (3, 600, 7),
            (8, 513, 16),
            (13, 1025, 12),
        ] {
            let a = pattern(m * depth, 0.01);
            let b = pattern(depth * n, 0.02);
            let seed = pattern(m * n, 0.5);
            let mut fast = seed.clone();
            simd::force_scalar(false);
            gemm(&mut fast, &a, &b, m, depth, n);
            let mut slow = seed;
            simd::force_scalar(true);
            gemm(&mut slow, &a, &b, m, depth, n);
            for (i, (&f, &s)) in fast.iter().zip(&slow).enumerate() {
                assert_eq!(f.to_bits(), s.to_bits(), "shape ({m},{depth},{n}) slot {i}");
            }
        }
    }

    #[test]
    fn dispatched_expand_powers_is_bitwise_scalar() {
        use crate::projection::simd;
        let _g = simd::lock_dispatch();
        for &cl in &[1usize, 2, 3, 4, 5, 7, 8, 17, 64, 65] {
            let mut r0 = pattern(cl, 0.07);
            let r1 = pattern(cl, 1.3);
            r0[0] = -0.0; // negative zero must match the scalar zero-skip
            if cl > 2 {
                r0[2] = 0.0;
            }
            let rows: Vec<&[f32]> = vec![&r0, &r1];
            let (orders, nm) = (3usize, 6usize);
            let mut p_fast = vec![f32::NAN; orders * 2 * cl];
            let mut m_fast = vec![0.25f64; 2 * nm];
            simd::force_scalar(false);
            expand_powers(&rows, 0, cl, orders, nm, &mut p_fast, &mut m_fast);
            let mut p_slow = vec![f32::NAN; orders * 2 * cl];
            let mut m_slow = vec![0.25f64; 2 * nm];
            simd::force_scalar(true);
            expand_powers(&rows, 0, cl, orders, nm, &mut p_slow, &mut m_slow);
            for (i, (&f, &s)) in p_fast.iter().zip(&p_slow).enumerate() {
                assert_eq!(f.to_bits(), s.to_bits(), "cl={cl} power slot {i}");
            }
            for (i, (&f, &s)) in m_fast.iter().zip(&m_slow).enumerate() {
                assert_eq!(f.to_bits(), s.to_bits(), "cl={cl} moment slot {i}");
            }
        }
    }

    #[test]
    fn sparse_matches_dense_gemm() {
        // A mostly-zero B in both dense and CSR form.
        let (rows, cl, k) = (5usize, 40usize, 9usize);
        let mut bdata = vec![0.0f32; cl * k];
        for t in 0..cl {
            if t % 3 == 0 {
                bdata[t * k + (t * 7) % k] = 1.5;
                bdata[t * k + (t * 5 + 2) % k] = -0.5;
            }
        }
        let mat = ProjectionMatrix { row0: 100, rows: cl, k, data: bdata.clone() };
        let sp = SparseChunk::from_dense(&mat);
        let a = pattern(rows * cl, 0.1);
        let mut dense = vec![0.0f32; rows * k];
        let mut sparse = vec![0.0f32; rows * k];
        gemm(&mut dense, &a, &bdata, rows, cl, k);
        gemm_sparse(&mut sparse, &a, &sp, 100, rows, cl, k);
        for (i, (&s, &d)) in sparse.iter().zip(&dense).enumerate() {
            assert!((s - d).abs() <= 1e-4 * (1.0 + d.abs()), "slot {i}: {s} vs {d}");
        }
    }
}
