//! Reproducible projection matrices.
//!
//! R ∈ R^{D×k} is defined *functionally*: entry (i, j) is a pure function
//! of (seed, i, j) via the counter-based RNG, so
//!
//! * any D-chunk of R can be (re)generated independently and in any
//!   order — the streaming pipeline never holds more than a chunk;
//! * the basic strategy uses one seed for every order, the alternative
//!   strategy derives an independent seed per order (paper §2.2).
//!
//! [`ProjectionMatrix`] materializes a chunk row-major for the fast
//! sketcher path; memory is `rows × k × 4` bytes.

use super::subgaussian::ProjectionDist;
use super::Strategy;

/// Full description of a projection scheme — everything needed to rebuild
/// sketches bit-identically.
#[derive(Clone, Debug, PartialEq)]
pub struct ProjectionSpec {
    pub seed: u64,
    pub k: usize,
    pub dist: ProjectionDist,
    pub strategy: Strategy,
}

impl ProjectionSpec {
    pub fn new(seed: u64, k: usize, dist: ProjectionDist, strategy: Strategy) -> Self {
        ProjectionSpec { seed, k, dist, strategy }
    }

    /// Seed used for sketch order `m` (1-based). Basic: shared; the
    /// alternative strategy decorrelates orders with distinct streams.
    pub fn seed_for_order(&self, m: usize) -> u64 {
        match self.strategy {
            Strategy::Basic => self.seed,
            Strategy::Alternative => self
                .seed
                .wrapping_add((m as u64).wrapping_mul(0xA076_1D64_78BD_642F)),
        }
    }

    /// Entry R^(order m)[i, j].
    #[inline]
    pub fn entry(&self, m: usize, i: u64, j: u64) -> f64 {
        self.dist.entry(self.seed_for_order(m), i, j)
    }

    /// Materialize rows `[row0, row0 + rows)` of R^(m), row-major f32.
    pub fn materialize(&self, m: usize, row0: usize, rows: usize) -> ProjectionMatrix {
        let mut data = vec![0.0f32; rows * self.k];
        self.materialize_into(m, row0, rows, &mut data);
        ProjectionMatrix { row0, rows, k: self.k, data }
    }

    /// Row-batched generation: fill `out` (`rows × k` row-major,
    /// preallocated) with rows `[row0, row0 + rows)` of R^(m). This is
    /// the path that feeds the GEMM sketch tiles — counter-hash output
    /// lands by direct slice writes, with no per-entry `Vec::push`
    /// capacity checks on the generation hot loop.
    pub fn materialize_into(&self, m: usize, row0: usize, rows: usize, out: &mut [f32]) {
        assert_eq!(out.len(), rows * self.k, "materialize_into buffer shape");
        if self.k == 0 {
            return;
        }
        let seed = self.seed_for_order(m);
        for (i, row) in out.chunks_mut(self.k).enumerate() {
            let gi = (row0 + i) as u64;
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = self.dist.entry(seed, gi, j as u64) as f32;
            }
        }
    }

    /// Number of distinct matrices the strategy needs for `orders` orders.
    pub fn matrix_count(&self, orders: usize) -> usize {
        match self.strategy {
            Strategy::Basic => 1,
            Strategy::Alternative => orders,
        }
    }
}

/// A materialized row-chunk of a projection matrix (row-major).
#[derive(Clone, Debug)]
pub struct ProjectionMatrix {
    pub row0: usize,
    pub rows: usize,
    pub k: usize,
    pub data: Vec<f32>,
}

impl ProjectionMatrix {
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i >= self.row0 && i < self.row0 + self.rows);
        let off = (i - self.row0) * self.k;
        &self.data[off..off + self.k]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(strategy: Strategy) -> ProjectionSpec {
        ProjectionSpec::new(99, 8, ProjectionDist::Normal, strategy)
    }

    #[test]
    fn chunked_equals_whole() {
        let s = spec(Strategy::Basic);
        let whole = s.materialize(1, 0, 32);
        let a = s.materialize(1, 0, 16);
        let b = s.materialize(1, 16, 16);
        for i in 0..16 {
            assert_eq!(whole.row(i), a.row(i));
            assert_eq!(whole.row(16 + i), b.row(16 + i));
        }
    }

    #[test]
    fn basic_shares_matrix_across_orders() {
        let s = spec(Strategy::Basic);
        assert_eq!(s.materialize(1, 0, 4).data, s.materialize(3, 0, 4).data);
        assert_eq!(s.matrix_count(3), 1);
    }

    #[test]
    fn alternative_gives_independent_matrices() {
        let s = spec(Strategy::Alternative);
        assert_ne!(s.materialize(1, 0, 4).data, s.materialize(2, 0, 4).data);
        assert_ne!(s.materialize(2, 0, 4).data, s.materialize(3, 0, 4).data);
        assert_eq!(s.matrix_count(3), 3);
    }

    #[test]
    fn materialize_into_matches_materialize() {
        for strategy in [Strategy::Basic, Strategy::Alternative] {
            let s = spec(strategy);
            let whole = s.materialize(2, 5, 12);
            let mut buf = vec![f32::NAN; 12 * s.k];
            s.materialize_into(2, 5, 12, &mut buf);
            assert_eq!(whole.data, buf);
        }
    }

    #[test]
    #[should_panic(expected = "buffer shape")]
    fn materialize_into_rejects_misshaped_buffer() {
        let s = spec(Strategy::Basic);
        let mut buf = vec![0.0f32; 7];
        s.materialize_into(1, 0, 4, &mut buf);
    }

    #[test]
    fn seed_changes_everything() {
        let a = spec(Strategy::Basic).materialize(1, 0, 4);
        let b = ProjectionSpec::new(100, 8, ProjectionDist::Normal, Strategy::Basic)
            .materialize(1, 0, 4);
        assert_ne!(a.data, b.data);
    }
}
