//! Random projection layer: distributions, reproducible chunked matrix
//! generation, the register-tiled GEMM sketch kernels, and the pure-rust
//! sketcher (CPU fallback / baseline).

pub mod gemm;
pub mod matrix;
pub mod simd;
pub mod sketcher;
pub mod subgaussian;

pub use matrix::{ProjectionMatrix, ProjectionSpec};
pub use subgaussian::ProjectionDist;

/// Which projection strategy (paper §2.1 vs §2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// One shared R for all sketch orders — simpler, lower variance on
    /// non-negative data (Lemma 3).
    Basic,
    /// Independent R per order — cross-order covariances vanish, making
    /// the analysis (and the margin MLE of Lemma 4) tractable.
    Alternative,
}

impl Strategy {
    pub fn as_str(&self) -> &'static str {
        match self {
            Strategy::Basic => "basic",
            Strategy::Alternative => "alternative",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "basic" => Ok(Strategy::Basic),
            "alternative" | "alt" => Ok(Strategy::Alternative),
            _ => anyhow::bail!("unknown strategy {s:?} (want basic|alternative)"),
        }
    }
}
