//! Runtime-dispatched SIMD micro-kernels for the two hot paths: the
//! 4×8 GEMM register tile at ingest and the f64-accumulated dot
//! products at query.
//!
//! ## Dispatch
//!
//! One kernel choice per process, detected at first use
//! ([`active`]): AVX on x86-64 (`is_x86_feature_detected!`), NEON on
//! aarch64, a portable unrolled fallback elsewhere. The scalar
//! reference kernels stay compiled on every target — they *are* the
//! semantics, and [`force_scalar`] (or `LPSKETCH_FORCE_SCALAR=1`)
//! pins dispatch to them so the bitwise-equality property suites can
//! exercise both sides on one machine. The serving metrics report the
//! choice as the `simd_kernel` label ([`active_kernel`]).
//!
//! ## The bitwise contract
//!
//! Every vector path reproduces its scalar reference **bitwise**, by
//! construction, not by tolerance:
//!
//! * [`dot_f32`]'s scalar contract is four independent f64
//!   accumulators over chunks of 4, a scalar tail, and the fixed final
//!   reduction `(acc0 + acc2) + (acc1 + acc3) + tail`. The AVX path
//!   maps the four accumulators onto the four lanes of one `__m256d`
//!   (`cvtps_pd` → `mul_pd` → `add_pd`, never FMA), the NEON path onto
//!   two `float64x2_t`s — identical operations per slot, in the same
//!   order, so identical roundings.
//! * The 4×8 GEMM tile accumulates `acc[i][j] += a_i[t]·b[t][j]` with
//!   `t` ascending; the vector paths keep one register per output row
//!   and use separate multiply and add (no FMA contraction), so every
//!   slot sees the scalar operation sequence.
//! * The power-ladder expansion walks `x, x², …` in f64 per entry; the
//!   AVX path runs four entries' ladders in lock-step lanes (same
//!   multiply chain per entry) and accumulates moments scalar-wise in
//!   entry order from the extracted lanes.
//!
//! f16 dots decode lanes exactly (f16 ⊂ f32) and then follow the same
//! accumulation contract, so the AVX F16C path and the portable decode
//! agree bitwise too.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Kernel families the dispatcher can select.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// The scalar reference (also what `force_scalar` pins).
    Scalar,
    /// Portable unrolled loops (no arch intrinsics; autovectorizable).
    Portable,
    /// aarch64 NEON.
    Neon,
    /// x86-64 AVX (+ F16C for f16 decodes when available).
    Avx,
}

impl Kernel {
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Portable => "portable",
            Kernel::Neon => "neon",
            Kernel::Avx => "avx",
        }
    }
}

/// 0 = follow detection (honouring the env override), 1 = forced
/// scalar, 2 = forced auto (test hook re-enabling detection).
static FORCE: AtomicU8 = AtomicU8::new(0);

static DETECTED: OnceLock<Kernel> = OnceLock::new();
static ENV_SCALAR: OnceLock<bool> = OnceLock::new();
#[cfg(target_arch = "x86_64")]
static F16C: OnceLock<bool> = OnceLock::new();

fn detected() -> Kernel {
    *DETECTED.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx") {
                return Kernel::Avx;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return Kernel::Neon;
            }
        }
        Kernel::Portable
    })
}

/// Whether the AVX paths may use F16C half-precision converts.
#[cfg(target_arch = "x86_64")]
fn f16c() -> bool {
    *F16C.get_or_init(|| std::arch::is_x86_feature_detected!("f16c"))
}

/// The kernel dispatch currently in effect.
pub fn active() -> Kernel {
    match FORCE.load(Ordering::Relaxed) {
        1 => Kernel::Scalar,
        2 => detected(),
        _ => {
            let env = *ENV_SCALAR.get_or_init(|| {
                std::env::var("LPSKETCH_FORCE_SCALAR").is_ok_and(|v| v == "1")
            });
            if env {
                Kernel::Scalar
            } else {
                detected()
            }
        }
    }
}

/// The `simd_kernel` metrics label.
pub fn active_kernel() -> &'static str {
    active().name()
}

/// Pin dispatch to the scalar reference (`true`) or back to detection
/// (`false`) — the property-suite hook for exercising both sides of
/// the bitwise contract in one process. Overrides
/// `LPSKETCH_FORCE_SCALAR`.
pub fn force_scalar(on: bool) {
    FORCE.store(if on { 1 } else { 2 }, Ordering::Relaxed);
}

/// Serializes tests that toggle [`force_scalar`]: the switch is
/// process-global, so concurrent toggling tests would race each
/// other's dispatch expectations. Dropping the guard restores
/// follow-the-environment dispatch.
#[cfg(test)]
pub(crate) fn lock_dispatch() -> DispatchGuard {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    DispatchGuard(match LOCK.lock() {
        Ok(g) => g,
        Err(e) => e.into_inner(),
    })
}

#[cfg(test)]
pub(crate) struct DispatchGuard(#[allow(dead_code)] std::sync::MutexGuard<'static, ()>);

#[cfg(test)]
impl Drop for DispatchGuard {
    fn drop(&mut self) {
        FORCE.store(0, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// f64-accumulated dot products
// ---------------------------------------------------------------------------

/// f64 dot product of two f32 sketch rows, SIMD-dispatched.
/// Bitwise-identical to [`dot_f32_scalar`] on every path.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if active() == Kernel::Avx {
        // SAFETY: dispatch only selects Avx after runtime detection.
        return unsafe { dot_f32_avx(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if active() == Kernel::Neon {
        // SAFETY: dispatch only selects Neon after runtime detection.
        return unsafe { dot_f32_neon(a, b) };
    }
    dot_f32_scalar(a, b)
}

/// The scalar reduction-order contract (see `estimator::dot` docs):
/// four independent f64 accumulators, chunks of 4, scalar tail, final
/// `(acc0 + acc2) + (acc1 + acc3) + tail`. Changing this sequence
/// changes every persisted estimate — it is pinned by the SIMD
/// equality suites and the bench guards.
pub fn dot_f32_scalar(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += (a[i] as f64) * (b[i] as f64);
        acc[1] += (a[i + 1] as f64) * (b[i + 1] as f64);
        acc[2] += (a[i + 2] as f64) * (b[i + 2] as f64);
        acc[3] += (a[i + 3] as f64) * (b[i + 3] as f64);
    }
    let mut tail = 0.0f64;
    for i in chunks * 4..a.len() {
        tail += (a[i] as f64) * (b[i] as f64);
    }
    (acc[0] + acc[2]) + (acc[1] + acc[3]) + tail
}

/// AVX dot: one `__m256d` whose lane `j` plays scalar `acc[j]`.
/// `cvtps_pd` is exact, `mul_pd`/`add_pd` round separately exactly as
/// the scalar's `*` then `+=` do — never FMA.
// SAFETY: caller must have verified AVX support (the dispatcher gates
// on `is_x86_feature_detected!("avx")`); slices may be any length, the
// tail loop covers the remainder.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn dot_f32_avx(a: &[f32], b: &[f32]) -> f64 {
    use std::arch::x86_64::*;
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 4;
    let mut acc = _mm256_setzero_pd();
    for c in 0..chunks {
        let i = c * 4;
        let av = _mm256_cvtps_pd(_mm_loadu_ps(a.as_ptr().add(i)));
        let bv = _mm256_cvtps_pd(_mm_loadu_ps(b.as_ptr().add(i)));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(av, bv));
    }
    let mut lanes = [0.0f64; 4];
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
    let mut tail = 0.0f64;
    for i in chunks * 4..a.len() {
        tail += (a[i] as f64) * (b[i] as f64);
    }
    (lanes[0] + lanes[2]) + (lanes[1] + lanes[3]) + tail
}

/// NEON dot: `acc[0..2]` and `acc[2..4]` live in two `float64x2_t`s;
/// separate `vmulq`/`vaddq` (no fused form), same final reduction.
// SAFETY: caller must have verified NEON support (always present on
// aarch64, gated by the dispatcher anyway); no pointer arithmetic past
// the checked chunk bounds.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot_f32_neon(a: &[f32], b: &[f32]) -> f64 {
    use std::arch::aarch64::*;
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 4;
    let mut acc01 = vdupq_n_f64(0.0);
    let mut acc23 = vdupq_n_f64(0.0);
    for c in 0..chunks {
        let i = c * 4;
        let av = vld1q_f32(a.as_ptr().add(i));
        let bv = vld1q_f32(b.as_ptr().add(i));
        acc01 = vaddq_f64(
            acc01,
            vmulq_f64(vcvt_f64_f32(vget_low_f32(av)), vcvt_f64_f32(vget_low_f32(bv))),
        );
        acc23 = vaddq_f64(
            acc23,
            vmulq_f64(vcvt_f64_f32(vget_high_f32(av)), vcvt_f64_f32(vget_high_f32(bv))),
        );
    }
    let (a0, a1) = (vgetq_lane_f64::<0>(acc01), vgetq_lane_f64::<1>(acc01));
    let (a2, a3) = (vgetq_lane_f64::<0>(acc23), vgetq_lane_f64::<1>(acc23));
    let mut tail = 0.0f64;
    for i in chunks * 4..a.len() {
        tail += (a[i] as f64) * (b[i] as f64);
    }
    (a0 + a2) + (a1 + a3) + tail
}

/// f64 dot of two f16-encoded rows: decode lanes exactly, then the
/// [`dot_f32_scalar`] contract. AVX+F16C decodes four halves per
/// `cvtph_ps` in registers; other targets decode per lane.
#[inline]
pub fn dot_f16_f16(a: &[u16], b: &[u16]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if active() == Kernel::Avx && f16c() {
        // SAFETY: gated on runtime AVX + F16C detection.
        return unsafe { dot_f16_f16_avx(a, b) };
    }
    dot_f16_f16_scalar(a, b)
}

/// Portable f16×f16 dot (the reference the AVX path matches bitwise).
pub fn dot_f16_f16_scalar(a: &[u16], b: &[u16]) -> f64 {
    use crate::core::quant::f16_bits_to_f32;
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += (f16_bits_to_f32(a[i]) as f64) * (f16_bits_to_f32(b[i]) as f64);
        acc[1] += (f16_bits_to_f32(a[i + 1]) as f64) * (f16_bits_to_f32(b[i + 1]) as f64);
        acc[2] += (f16_bits_to_f32(a[i + 2]) as f64) * (f16_bits_to_f32(b[i + 2]) as f64);
        acc[3] += (f16_bits_to_f32(a[i + 3]) as f64) * (f16_bits_to_f32(b[i + 3]) as f64);
    }
    let mut tail = 0.0f64;
    for i in chunks * 4..a.len() {
        tail += (f16_bits_to_f32(a[i]) as f64) * (f16_bits_to_f32(b[i]) as f64);
    }
    (acc[0] + acc[2]) + (acc[1] + acc[3]) + tail
}

// SAFETY: caller must have verified AVX+F16C support (dispatcher gates
// on both); loads stay within the checked chunk bounds.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx", enable = "f16c")]
unsafe fn dot_f16_f16_avx(a: &[u16], b: &[u16]) -> f64 {
    use std::arch::x86_64::*;
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 4;
    let mut acc = _mm256_setzero_pd();
    for c in 0..chunks {
        let i = c * 4;
        // Four halves in the low 64 bits; cvtph_ps decodes them exactly.
        let ah = _mm_loadl_epi64(a.as_ptr().add(i) as *const __m128i);
        let bh = _mm_loadl_epi64(b.as_ptr().add(i) as *const __m128i);
        let av = _mm256_cvtps_pd(_mm_cvtph_ps(ah));
        let bv = _mm256_cvtps_pd(_mm_cvtph_ps(bh));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(av, bv));
    }
    let mut lanes = [0.0f64; 4];
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
    let mut tail = 0.0f64;
    for i in chunks * 4..a.len() {
        use crate::core::quant::f16_bits_to_f32;
        tail += (f16_bits_to_f32(a[i]) as f64) * (f16_bits_to_f32(b[i]) as f64);
    }
    (lanes[0] + lanes[2]) + (lanes[1] + lanes[3]) + tail
}

/// f64 dot of an f32 row against an f16-encoded row — the serving
/// top-k shape (f32 query sketches × quantized segment panels).
#[inline]
pub fn dot_f32_f16(a: &[f32], b: &[u16]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if active() == Kernel::Avx && f16c() {
        // SAFETY: gated on runtime AVX + F16C detection.
        return unsafe { dot_f32_f16_avx(a, b) };
    }
    dot_f32_f16_scalar(a, b)
}

/// Portable f32×f16 dot (reference for the AVX path).
pub fn dot_f32_f16_scalar(a: &[f32], b: &[u16]) -> f64 {
    use crate::core::quant::f16_bits_to_f32;
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += (a[i] as f64) * (f16_bits_to_f32(b[i]) as f64);
        acc[1] += (a[i + 1] as f64) * (f16_bits_to_f32(b[i + 1]) as f64);
        acc[2] += (a[i + 2] as f64) * (f16_bits_to_f32(b[i + 2]) as f64);
        acc[3] += (a[i + 3] as f64) * (f16_bits_to_f32(b[i + 3]) as f64);
    }
    let mut tail = 0.0f64;
    for i in chunks * 4..a.len() {
        tail += (a[i] as f64) * (f16_bits_to_f32(b[i]) as f64);
    }
    (acc[0] + acc[2]) + (acc[1] + acc[3]) + tail
}

// SAFETY: caller must have verified AVX+F16C support (dispatcher gates
// on both); loads stay within the checked chunk bounds.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx", enable = "f16c")]
unsafe fn dot_f32_f16_avx(a: &[f32], b: &[u16]) -> f64 {
    use std::arch::x86_64::*;
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 4;
    let mut acc = _mm256_setzero_pd();
    for c in 0..chunks {
        let i = c * 4;
        let av = _mm256_cvtps_pd(_mm_loadu_ps(a.as_ptr().add(i)));
        let bh = _mm_loadl_epi64(b.as_ptr().add(i) as *const __m128i);
        let bv = _mm256_cvtps_pd(_mm_cvtph_ps(bh));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(av, bv));
    }
    let mut lanes = [0.0f64; 4];
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
    let mut tail = 0.0f64;
    for i in chunks * 4..a.len() {
        use crate::core::quant::f16_bits_to_f32;
        tail += (a[i] as f64) * (f16_bits_to_f32(b[i]) as f64);
    }
    (lanes[0] + lanes[2]) + (lanes[1] + lanes[3]) + tail
}

// ---------------------------------------------------------------------------
// The 4×8 GEMM register tile
// ---------------------------------------------------------------------------

/// Update a full 4×8 accumulator tile: for `t` in `0..tc`,
/// `acc[i][j] += a[i][t] · b[(t0+t)·n + j0 + j]`. Dispatched; every
/// path performs the identical per-slot multiply-then-add sequence
/// (see module docs), so the tiled GEMM stays bitwise independent of
/// the kernel choice.
#[inline]
pub fn gemm_tile_4x8(
    acc: &mut [[f32; 8]; 4],
    a: [&[f32]; 4],
    b: &[f32],
    t0: usize,
    tc: usize,
    n: usize,
    j0: usize,
) {
    #[cfg(target_arch = "x86_64")]
    if active() == Kernel::Avx {
        // SAFETY: dispatch only selects Avx after runtime detection.
        unsafe { gemm_tile_4x8_avx(acc, a, b, t0, tc, n, j0) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if active() == Kernel::Neon {
        // SAFETY: dispatch only selects Neon after runtime detection.
        unsafe { gemm_tile_4x8_neon(acc, a, b, t0, tc, n, j0) };
        return;
    }
    gemm_tile_4x8_scalar(acc, a, b, t0, tc, n, j0)
}

/// Scalar reference tile (the seed kernel's exact inner loop).
pub fn gemm_tile_4x8_scalar(
    acc: &mut [[f32; 8]; 4],
    a: [&[f32]; 4],
    b: &[f32],
    t0: usize,
    tc: usize,
    n: usize,
    j0: usize,
) {
    for t in 0..tc {
        let bt = &b[(t0 + t) * n + j0..][..8];
        let (x0, x1, x2, x3) = (a[0][t], a[1][t], a[2][t], a[3][t]);
        for j in 0..8 {
            let bv = bt[j];
            acc[0][j] += x0 * bv;
            acc[1][j] += x1 * bv;
            acc[2][j] += x2 * bv;
            acc[3][j] += x3 * bv;
        }
    }
}

/// AVX tile: one `__m256` per output row, broadcast `a_i[t]`, separate
/// `mul_ps`/`add_ps` (never FMA — fusing would change roundings vs the
/// scalar reference).
// SAFETY: caller must have verified AVX support and pass rows of at
// least 8 columns per tile step, which the tiled driver guarantees.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn gemm_tile_4x8_avx(
    acc: &mut [[f32; 8]; 4],
    a: [&[f32]; 4],
    b: &[f32],
    t0: usize,
    tc: usize,
    n: usize,
    j0: usize,
) {
    use std::arch::x86_64::*;
    let mut r0 = _mm256_loadu_ps(acc[0].as_ptr());
    let mut r1 = _mm256_loadu_ps(acc[1].as_ptr());
    let mut r2 = _mm256_loadu_ps(acc[2].as_ptr());
    let mut r3 = _mm256_loadu_ps(acc[3].as_ptr());
    for t in 0..tc {
        let bt = _mm256_loadu_ps(b.as_ptr().add((t0 + t) * n + j0));
        r0 = _mm256_add_ps(r0, _mm256_mul_ps(_mm256_set1_ps(a[0][t]), bt));
        r1 = _mm256_add_ps(r1, _mm256_mul_ps(_mm256_set1_ps(a[1][t]), bt));
        r2 = _mm256_add_ps(r2, _mm256_mul_ps(_mm256_set1_ps(a[2][t]), bt));
        r3 = _mm256_add_ps(r3, _mm256_mul_ps(_mm256_set1_ps(a[3][t]), bt));
    }
    _mm256_storeu_ps(acc[0].as_mut_ptr(), r0);
    _mm256_storeu_ps(acc[1].as_mut_ptr(), r1);
    _mm256_storeu_ps(acc[2].as_mut_ptr(), r2);
    _mm256_storeu_ps(acc[3].as_mut_ptr(), r3);
}

/// NEON tile: two `float32x4_t`s per output row, separate
/// `vmulq`/`vaddq` (no fused form).
// SAFETY: caller must have verified NEON support and pass rows of at
// least 8 columns per tile step, which the tiled driver guarantees.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn gemm_tile_4x8_neon(
    acc: &mut [[f32; 8]; 4],
    a: [&[f32]; 4],
    b: &[f32],
    t0: usize,
    tc: usize,
    n: usize,
    j0: usize,
) {
    use std::arch::aarch64::*;
    let mut lo = [vdupq_n_f32(0.0); 4];
    let mut hi = [vdupq_n_f32(0.0); 4];
    for i in 0..4 {
        lo[i] = vld1q_f32(acc[i].as_ptr());
        hi[i] = vld1q_f32(acc[i].as_ptr().add(4));
    }
    for t in 0..tc {
        let base = b.as_ptr().add((t0 + t) * n + j0);
        let blo = vld1q_f32(base);
        let bhi = vld1q_f32(base.add(4));
        for i in 0..4 {
            let x = vdupq_n_f32(a[i][t]);
            lo[i] = vaddq_f32(lo[i], vmulq_f32(x, blo));
            hi[i] = vaddq_f32(hi[i], vmulq_f32(x, bhi));
        }
    }
    for i in 0..4 {
        vst1q_f32(acc[i].as_mut_ptr(), lo[i]);
        vst1q_f32(acc[i].as_mut_ptr().add(4), hi[i]);
    }
}

// ---------------------------------------------------------------------------
// Power-ladder expansion
// ---------------------------------------------------------------------------

/// Expand one row chunk's power ladder into the order-major powers
/// panel and fold the chunk into the row's moments — the vectorizable
/// inner body of `gemm::expand_powers`. `row` is the chunk slice
/// (`cl` entries), `r` the row index, `n` the row count; layout and
/// semantics match the scalar reference in `projection::gemm` exactly
/// (f64 ladder, f32 power casts, zero entries contribute nothing to
/// the moments).
#[allow(clippy::too_many_arguments)]
pub fn expand_row(
    row: &[f32],
    r: usize,
    n: usize,
    cl: usize,
    orders: usize,
    nm: usize,
    powers: &mut [f32],
    mrow: &mut [f64],
) {
    #[cfg(target_arch = "x86_64")]
    if active() == Kernel::Avx {
        // SAFETY: dispatch only selects Avx after runtime detection.
        unsafe { expand_row_avx(row, r, n, cl, orders, nm, powers, mrow) };
        return;
    }
    expand_row_scalar(row, r, n, cl, orders, nm, powers, mrow)
}

/// Scalar reference expansion (the seed `expand_powers` body for one
/// row).
#[allow(clippy::too_many_arguments)]
pub fn expand_row_scalar(
    row: &[f32],
    r: usize,
    n: usize,
    cl: usize,
    orders: usize,
    nm: usize,
    powers: &mut [f32],
    mrow: &mut [f64],
) {
    debug_assert_eq!(mrow.len(), nm);
    for (t, &x) in row.iter().enumerate() {
        if x == 0.0 {
            // Zero entries contribute nothing; the powers slot still
            // needs a write because the buffer is reused across chunks.
            for m in 0..orders {
                powers[(m * n + r) * cl + t] = 0.0;
            }
            continue;
        }
        let xf = x as f64;
        let mut ladder = 1.0f64;
        for (m, slot) in mrow.iter_mut().enumerate() {
            ladder *= xf;
            if m < orders {
                powers[(m * n + r) * cl + t] = ladder as f32;
            }
            *slot += ladder;
        }
    }
}

/// AVX expansion: four entries' f64 ladders run in lock-step lanes
/// (`mul_pd` per rung — each lane performs exactly the scalar ladder's
/// multiply chain), rung casts go out via `cvtpd_ps` (round-to-nearest,
/// identical to the scalar `as f32`), and moments accumulate
/// scalar-wise from the extracted lanes **in entry order with the zero
/// skip**, so the result is bitwise-identical to
/// [`expand_row_scalar`].
// SAFETY: caller must have verified AVX support (dispatcher-gated);
// all lane extracts index constant positions within one `__m256d`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
#[allow(clippy::too_many_arguments)]
unsafe fn expand_row_avx(
    row: &[f32],
    r: usize,
    n: usize,
    cl: usize,
    orders: usize,
    nm: usize,
    powers: &mut [f32],
    mrow: &mut [f64],
) {
    use std::arch::x86_64::*;
    debug_assert_eq!(mrow.len(), nm);
    let quads = row.len() / 4;
    let mut lanes = [0.0f64; 4];
    for q in 0..quads {
        let t = q * 4;
        let x4 = _mm256_cvtps_pd(_mm_loadu_ps(row.as_ptr().add(t)));
        // Lane mask: true iff the entry is not ±0.0 (NaN stays true,
        // matching the scalar `x == 0.0` skip). ANDing the stored rung
        // with it turns a -0.0 entry's -0.0 rung into the +0.0 the
        // scalar skip writes, and is a bit-preserving no-op elsewhere.
        let nz = _mm256_cmp_pd::<_CMP_NEQ_UQ>(x4, _mm256_setzero_pd());
        let mut ladder = _mm256_set1_pd(1.0);
        for m in 0..nm {
            ladder = _mm256_mul_pd(ladder, x4);
            if m < orders {
                // Contiguous in t: 4 power slots in one store.
                let pw4 = _mm256_cvtpd_ps(_mm256_and_pd(ladder, nz));
                _mm_storeu_ps(powers.as_mut_ptr().add((m * n + r) * cl + t), pw4);
            }
            _mm256_storeu_pd(lanes.as_mut_ptr(), ladder);
            // Moments fold scalar-wise in entry order; zero entries are
            // skipped exactly as the scalar path skips them (adding
            // their 0.0 rung could still flip a -0.0 accumulator).
            for (lane, &l) in lanes.iter().enumerate() {
                if row[t + lane] != 0.0 {
                    mrow[m] += l;
                }
            }
        }
    }
    // Ragged tail at entry offsets quads*4.. — the scalar body verbatim
    // (the power rows are strided by cl, so the tail cannot be handled
    // by re-slicing `powers`).
    for t in quads * 4..row.len() {
        let x = row[t];
        if x == 0.0 {
            for m in 0..orders {
                powers[(m * n + r) * cl + t] = 0.0;
            }
            continue;
        }
        let xf = x as f64;
        let mut ladder = 1.0f64;
        for (m, slot) in mrow.iter_mut().enumerate() {
            ladder *= xf;
            if m < orders {
                powers[(m * n + r) * cl + t] = ladder as f32;
            }
            *slot += ladder;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample(rng: &mut Rng, n: usize, scale: f64) -> Vec<f32> {
        (0..n).map(|_| ((rng.next_f64() - 0.5) * 2.0 * scale) as f32).collect()
    }

    #[test]
    fn dispatch_reports_a_known_kernel() {
        let _g = lock_dispatch();
        let name = active_kernel();
        assert!(["avx", "neon", "portable", "scalar"].contains(&name), "{name}");
        force_scalar(true);
        assert_eq!(active_kernel(), "scalar");
        force_scalar(false);
        assert_eq!(active(), detected());
    }

    #[test]
    fn dot_dispatched_is_bitwise_scalar() {
        let mut rng = Rng::new(31);
        let _g = lock_dispatch();
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 15, 16, 63, 64, 65, 257] {
            let a = sample(&mut rng, n, 3.0);
            let b = sample(&mut rng, n, 3.0);
            force_scalar(false);
            let fast = dot_f32(&a, &b);
            force_scalar(true);
            let slow = dot_f32(&a, &b);
            assert_eq!(fast.to_bits(), slow.to_bits(), "n={n}");
            assert_eq!(slow.to_bits(), dot_f32_scalar(&a, &b).to_bits(), "n={n}");
        }
    }

    #[test]
    fn f16_dots_match_their_scalar_reference_bitwise() {
        use crate::core::quant::f32_to_f16_bits;
        let mut rng = Rng::new(37);
        let _g = lock_dispatch();
        for n in [0usize, 1, 3, 4, 5, 8, 31, 64, 130] {
            let a = sample(&mut rng, n, 2.0);
            let b = sample(&mut rng, n, 2.0);
            let ah: Vec<u16> = a.iter().map(|&x| f32_to_f16_bits(x)).collect();
            let bh: Vec<u16> = b.iter().map(|&x| f32_to_f16_bits(x)).collect();
            force_scalar(false);
            let fast_hh = dot_f16_f16(&ah, &bh);
            let fast_fh = dot_f32_f16(&a, &bh);
            force_scalar(true);
            assert_eq!(fast_hh.to_bits(), dot_f16_f16(&ah, &bh).to_bits(), "hh n={n}");
            assert_eq!(fast_fh.to_bits(), dot_f32_f16(&a, &bh).to_bits(), "fh n={n}");
            assert_eq!(
                dot_f16_f16(&ah, &bh).to_bits(),
                dot_f16_f16_scalar(&ah, &bh).to_bits()
            );
        }
    }

    #[test]
    fn gemm_tile_dispatched_is_bitwise_scalar() {
        let mut rng = Rng::new(41);
        let _g = lock_dispatch();
        for tc in [1usize, 2, 7, 8, 64, 511, 512] {
            let n = 24;
            let j0 = 8;
            let a: Vec<Vec<f32>> = (0..4).map(|_| sample(&mut rng, tc, 0.5)).collect();
            let b = sample(&mut rng, (tc + 1) * n, 0.5);
            let seed: Vec<[f32; 8]> =
                (0..4).map(|i| std::array::from_fn(|j| (i * 8 + j) as f32 * 0.1)).collect();
            let arows = [a[0].as_slice(), a[1].as_slice(), a[2].as_slice(), a[3].as_slice()];
            let mut fast: [[f32; 8]; 4] = [seed[0], seed[1], seed[2], seed[3]];
            force_scalar(false);
            gemm_tile_4x8(&mut fast, arows, &b, 0, tc, n, j0);
            let mut slow: [[f32; 8]; 4] = [seed[0], seed[1], seed[2], seed[3]];
            force_scalar(true);
            gemm_tile_4x8(&mut slow, arows, &b, 0, tc, n, j0);
            for i in 0..4 {
                for j in 0..8 {
                    assert_eq!(
                        fast[i][j].to_bits(),
                        slow[i][j].to_bits(),
                        "tc={tc} slot ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn expand_row_dispatched_is_bitwise_scalar() {
        let mut rng = Rng::new(43);
        let _g = lock_dispatch();
        for cl in [1usize, 3, 4, 5, 8, 17, 64] {
            let (orders, nm, n, r) = (3usize, 6usize, 2usize, 1usize);
            let mut row = sample(&mut rng, cl, 1.5);
            if cl > 2 {
                row[0] = -0.0; // negative zero must store +0.0 powers
                row[1] = 0.0; // exercise the zero-skip
                row[cl - 1] = 0.0;
            }
            let mut p_fast = vec![f32::NAN; orders * n * cl];
            let mut m_fast = vec![0.1f64; nm];
            force_scalar(false);
            expand_row(&row, r, n, cl, orders, nm, &mut p_fast, &mut m_fast);
            let mut p_slow = vec![f32::NAN; orders * n * cl];
            let mut m_slow = vec![0.1f64; nm];
            force_scalar(true);
            expand_row(&row, r, n, cl, orders, nm, &mut p_slow, &mut m_slow);
            for m in 0..orders {
                for t in 0..cl {
                    let idx = (m * n + r) * cl + t;
                    assert_eq!(
                        p_fast[idx].to_bits(),
                        p_slow[idx].to_bits(),
                        "cl={cl} m={m} t={t}"
                    );
                }
            }
            for m in 0..nm {
                assert_eq!(m_fast[m].to_bits(), m_slow[m].to_bits(), "cl={cl} moment {m}");
            }
        }
    }
}
