//! Pure-rust power sketcher — the CPU mirror of the L1 Pallas kernel.
//!
//! Used (a) as the runtime fallback for shapes with no AOT artifact,
//! (b) as the reference in PJRT cross-checks, and (c) by the Monte-Carlo
//! experiments, which need millions of small sketches where PJRT dispatch
//! overhead would dominate.
//!
//! Two CPU paths share the chunked-R machinery:
//!
//! * [`Sketcher::sketch_rows`] — the per-row reference path: one pass
//!   over x per D-chunk, Hadamard power ladder per entry, feature-outer
//!   axpy into per-row [`RowSketch`]es. Kept as the oracle the tiled
//!   path is property-tested and benchmarked against.
//! * [`Sketcher::sketch_block`] / [`Sketcher::sketch_block_into`] — the
//!   ingest hot path: per D-chunk the data block is power-expanded
//!   *once* into an order-major powers matrix, then the register-tiled
//!   GEMM micro-kernels in [`super::gemm`] project it against the
//!   materialized R chunk (CSR variant for sparse three-point R),
//!   sharded row-band-wise across worker threads. Output lands directly
//!   in a [`ColumnarBlock`] — the `SketchArena` order-major layout — so
//!   block ingest never allocates per-row AoS sketches and the
//!   store→arena repack disappears.
//!
//! Sparse three-point distributions take a skip path on both routes
//! (zero R entries never touch the accumulators).
//!
//! ## Sides (alternative strategy)
//!
//! Under the paper's alternative strategy (§2.2), each inner-product
//! *pair* shares one matrix: u₂&v₂ use R⁽ᵃ⁾, u₃&v₁ use R⁽ᵇ⁾, u₁&v₃ use
//! R⁽ᶜ⁾. So the left ("u") sketch of order m uses matrix id m while the
//! right ("v") sketch of order m uses matrix id p−m. Since every stored
//! row may appear on either side of a pair query, alternative-strategy
//! rows carry TWO sketch sets — a real 2× storage overhead over the
//! basic strategy that E2/E3 report alongside the variance comparison.
//! (Basic strategy: the sides coincide and only one set is stored.)

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::gemm::{self, SparseChunk};
use super::matrix::{ProjectionMatrix, ProjectionSpec};
use super::Strategy;
use crate::core::marginals::Moments;
use crate::core::quant::{PanelQuant, PanelStore, RowView};

/// Power sketches of one row for one side: `u(m)` is the k-vector
/// (x^∘m)ᵀ R^(id), m = 1..=orders.
#[derive(Clone, Debug, PartialEq)]
pub struct SketchSet {
    pub orders: usize,
    pub k: usize,
    /// Row-major (orders × k), f32 to match the PJRT artifacts.
    pub data: Vec<f32>,
}

impl SketchSet {
    pub fn zeros(orders: usize, k: usize) -> Self {
        SketchSet { orders, k, data: vec![0.0; orders * k] }
    }

    #[inline]
    pub fn u(&self, m: usize) -> &[f32] {
        debug_assert!(m >= 1 && m <= self.orders);
        &self.data[(m - 1) * self.k..m * self.k]
    }

    #[inline]
    pub fn u_mut(&mut self, m: usize) -> &mut [f32] {
        &mut self.data[(m - 1) * self.k..m * self.k]
    }

    /// ‖u(m)‖² in f64 (the MLE cubic needs it).
    pub fn norm2(&self, m: usize) -> f64 {
        self.u(m).iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Sketches are additive over D-chunks (linearity invariant).
    pub fn merge(&mut self, other: &SketchSet) {
        assert_eq!((self.orders, self.k), (other.orders, other.k));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }
}

/// Sketches + marginal moments of one row — everything the estimators
/// need, on both pair sides.
#[derive(Clone, Debug)]
pub struct RowSketch {
    /// Left-side sketches: order m projected with matrix id m.
    pub uside: SketchSet,
    /// Right-side sketches (alternative strategy only): order m projected
    /// with matrix id p−m. `None` ⇒ identical to `uside` (basic strategy).
    pub vside_data: Option<SketchSet>,
    /// Moments Σ x^m for m = 1..2(p-1), f64.
    pub moments: Moments,
}

impl RowSketch {
    /// The sketch set to use when this row is the *right* element of a
    /// pair query.
    #[inline]
    pub fn vside(&self) -> &SketchSet {
        self.vside_data.as_ref().unwrap_or(&self.uside)
    }

    /// Bytes of sketch payload (storage accounting for E7).
    pub fn sketch_bytes(&self) -> usize {
        let one = self.uside.data.len() * std::mem::size_of::<f32>();
        let sides = if self.vside_data.is_some() { 2 } else { 1 };
        one * sides + self.moments.0.len() * std::mem::size_of::<f64>()
    }

    pub fn merge(&mut self, other: &RowSketch) {
        self.uside.merge(&other.uside);
        match (&mut self.vside_data, &other.vside_data) {
            (Some(a), Some(b)) => a.merge(b),
            (None, None) => {}
            _ => panic!("cannot merge sketches of different strategies"),
        }
        self.moments.merge(&other.moments);
    }
}

/// Columnar (arena-layout) sketches + moments of one ingested block:
/// the structure-of-arrays output of [`Sketcher::sketch_block_into`].
///
/// Layout matches [`crate::core::arena::SketchArena`] exactly —
/// order-major sketch panels (`u[((m-1)·rows + r)·k ..][..k]` is u_m of
/// block row `r`) — so landing a block in the arena (or a store
/// segment) is one contiguous copy per order per side, with no per-row
/// AoS allocation in between. Moments are row-major f64 (`rows × nm`,
/// nm = 2(p−1)), everything `core/mle.rs` consumes.
///
/// Sketch panels live in a [`PanelStore`]: plain f32 (the sketcher's
/// output and the bitwise-reference encoding) or a quantized codec
/// (f16/bf16/i8) chosen at the store boundary. Quantized decode is
/// value-exact — the decoded f32 *is* the stored value — so views over
/// any encoding feed the same estimator kernels; moments always stay
/// f64. Mutating accessors and the raw `&[f32]` panel accessors require
/// the f32 encoding (ingest/WAL paths never quantize).
#[derive(Clone, Debug, PartialEq)]
pub struct ColumnarBlock {
    orders: usize,
    k: usize,
    /// Moment orders per row (2(p−1)).
    nm: usize,
    rows: usize,
    /// Order-major u-side sketches.
    u: PanelStore,
    /// Order-major v-side sketches (alternative strategy only); `None`
    /// ⇒ the sides coincide, mirroring [`RowSketch::vside`].
    v: Option<PanelStore>,
    /// Row-major marginal moments Σ x^m, m = 1..=nm, f64.
    moments: Vec<f64>,
}

impl ColumnarBlock {
    pub fn zeros(orders: usize, k: usize, nm: usize, rows: usize, two_sided: bool) -> Self {
        ColumnarBlock {
            orders,
            k,
            nm,
            rows,
            u: PanelStore::F32(vec![0.0; orders * rows * k]),
            v: two_sided.then(|| PanelStore::F32(vec![0.0; orders * rows * k])),
            moments: vec![0.0; rows * nm],
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn orders(&self) -> usize {
        self.orders
    }

    pub fn moment_orders(&self) -> usize {
        self.nm
    }

    pub fn is_two_sided(&self) -> bool {
        self.v.is_some()
    }

    /// The panel encoding (both sides always share one). [`PanelQuant::None`]
    /// ⇒ plain f32, the sketcher-output / WAL / bitwise-reference form.
    pub fn encoding(&self) -> PanelQuant {
        self.u.encoding()
    }

    /// The raw f32 panel behind `store`, for the accessors that predate
    /// quantized panels. Those accessors are only reachable on f32
    /// blocks (ingest output, WAL records, per-row reference paths);
    /// serving code uses the encoding-agnostic `*_view` accessors.
    #[track_caller]
    fn f32_panel(store: &PanelStore) -> &[f32] {
        match store {
            PanelStore::F32(v) => v,
            other => panic!(
                "raw f32 panel access on a {}-encoded block; use the view accessors",
                other.encoding().name()
            ),
        }
    }

    /// u_m sketch of block row `r` (f32 blocks only — see
    /// [`ColumnarBlock::u_view`] for the encoding-agnostic accessor).
    #[inline]
    #[track_caller]
    pub fn u_row(&self, m: usize, r: usize) -> &[f32] {
        debug_assert!(m >= 1 && m <= self.orders && r < self.rows);
        let off = ((m - 1) * self.rows + r) * self.k;
        &Self::f32_panel(&self.u)[off..off + self.k]
    }

    /// v_m sketch of block row `r`; falls back to the u side under the
    /// basic strategy (the sides coincide). f32 blocks only.
    #[inline]
    #[track_caller]
    pub fn v_row(&self, m: usize, r: usize) -> &[f32] {
        match &self.v {
            Some(v) => {
                debug_assert!(m >= 1 && m <= self.orders && r < self.rows);
                let off = ((m - 1) * self.rows + r) * self.k;
                &Self::f32_panel(v)[off..off + self.k]
            }
            None => self.u_row(m, r),
        }
    }

    /// u_m sketch of block row `r` as a lane-decodable [`RowView`] —
    /// works for every panel encoding; kernels decode in registers.
    #[inline]
    pub fn u_view(&self, m: usize, r: usize) -> RowView<'_> {
        debug_assert!(m >= 1 && m <= self.orders && r < self.rows);
        let off = ((m - 1) * self.rows + r) * self.k;
        self.u.view(m - 1, off, self.k)
    }

    /// v_m sketch of block row `r` as a [`RowView`]; falls back to the
    /// u side under the basic strategy.
    #[inline]
    pub fn v_view(&self, m: usize, r: usize) -> RowView<'_> {
        match &self.v {
            Some(v) => {
                debug_assert!(m >= 1 && m <= self.orders && r < self.rows);
                let off = ((m - 1) * self.rows + r) * self.k;
                v.view(m - 1, off, self.k)
            }
            None => self.u_view(m, r),
        }
    }

    /// The contiguous `rows × k` u-side panel of order `m` (f32 blocks
    /// only — WAL encode and pre-v5 persistence, which are never
    /// quantized).
    #[track_caller]
    pub fn u_order(&self, m: usize) -> &[f32] {
        debug_assert!(m >= 1 && m <= self.orders);
        let off = (m - 1) * self.rows * self.k;
        &Self::f32_panel(&self.u)[off..off + self.rows * self.k]
    }

    /// The contiguous `rows × k` v-side panel of order `m`
    /// (`None` under the basic strategy). f32 blocks only.
    #[track_caller]
    pub fn v_order(&self, m: usize) -> Option<&[f32]> {
        self.v.as_ref().map(|v| {
            debug_assert!(m >= 1 && m <= self.orders);
            let off = (m - 1) * self.rows * self.k;
            &Self::f32_panel(v)[off..off + self.rows * self.k]
        })
    }

    /// Decode the `rows × k` u-side panel of order `m` into `out`
    /// (encoding-agnostic bulk export: arena landing, WAL re-encode).
    pub fn decode_u_order_into(&self, m: usize, out: &mut [f32]) {
        debug_assert!(m >= 1 && m <= self.orders);
        debug_assert_eq!(out.len(), self.rows * self.k);
        self.u.decode_into(m - 1, (m - 1) * self.rows * self.k, out);
    }

    /// Decode the `rows × k` v-side panel of order `m` into `out`;
    /// falls back to the u side under the basic strategy.
    pub fn decode_v_order_into(&self, m: usize, out: &mut [f32]) {
        match &self.v {
            Some(v) => {
                debug_assert!(m >= 1 && m <= self.orders);
                debug_assert_eq!(out.len(), self.rows * self.k);
                v.decode_into(m - 1, (m - 1) * self.rows * self.k, out);
            }
            None => self.decode_u_order_into(m, out),
        }
    }

    /// The u-side panel store (persistence writers serialize it as-is).
    pub fn u_store(&self) -> &PanelStore {
        &self.u
    }

    /// The v-side panel store (`None` under the basic strategy).
    pub fn v_store(&self) -> Option<&PanelStore> {
        self.v.as_ref()
    }

    /// Mutable f32 panel + moment buffers — the sketcher's output
    /// surface. Panics unless the block is f32-encoded: sketch output
    /// is always written in f32; quantization happens later, at the
    /// store boundary.
    #[track_caller]
    fn f32_bufs_mut(&mut self) -> (&mut [f32], Option<&mut [f32]>, &mut [f64]) {
        let ColumnarBlock { u, v, moments, .. } = self;
        fn panel(store: &mut PanelStore) -> &mut [f32] {
            match store {
                PanelStore::F32(b) => b.as_mut_slice(),
                other => panic!(
                    "sketch output block is {}-encoded; sketching writes f32",
                    other.encoding().name()
                ),
            }
        }
        (panel(u), v.as_mut().map(panel), moments.as_mut_slice())
    }

    /// All moments of block row `r` (orders 1..=nm).
    #[inline]
    pub fn moments_row(&self, r: usize) -> &[f64] {
        &self.moments[r * self.nm..(r + 1) * self.nm]
    }

    /// The whole row-major `rows × nm` moment buffer (bulk persistence).
    pub fn moments_all(&self) -> &[f64] {
        &self.moments
    }

    /// Reassemble a block from raw buffers — the persistence-v2 load
    /// path, which reads each (order, side) panel as one contiguous
    /// chunk and must land it verbatim. Panics on shape/length mismatch
    /// (callers validate declared sizes before reading the buffers).
    pub fn from_parts(
        orders: usize,
        k: usize,
        nm: usize,
        rows: usize,
        u: Vec<f32>,
        v: Option<Vec<f32>>,
        moments: Vec<f64>,
    ) -> Self {
        assert_eq!(u.len(), orders * rows * k, "u panel length mismatch");
        if let Some(v) = &v {
            assert_eq!(v.len(), orders * rows * k, "v panel length mismatch");
        }
        assert_eq!(moments.len(), rows * nm, "moment buffer length mismatch");
        ColumnarBlock {
            orders,
            k,
            nm,
            rows,
            u: PanelStore::F32(u),
            v: v.map(PanelStore::F32),
            moments,
        }
    }

    /// Reassemble a block from already-encoded panel stores — the
    /// persistence-v5 / segfile-v3 load path, which reads each side's
    /// store verbatim (any encoding). Panics on shape/length/encoding
    /// mismatch (callers validate declared sizes before allocating).
    pub fn from_stores(
        orders: usize,
        k: usize,
        nm: usize,
        rows: usize,
        u: PanelStore,
        v: Option<PanelStore>,
        moments: Vec<f64>,
    ) -> Self {
        assert_eq!(u.len(), orders * rows * k, "u panel length mismatch");
        if let Some(scales) = u.i8_scales() {
            assert_eq!(scales.len(), orders, "u i8 scale count mismatch");
        }
        if let Some(v) = &v {
            assert_eq!(v.len(), orders * rows * k, "v panel length mismatch");
            assert_eq!(v.encoding(), u.encoding(), "panel encoding differs across sides");
            if let Some(scales) = v.i8_scales() {
                assert_eq!(scales.len(), orders, "v i8 scale count mismatch");
            }
        }
        assert_eq!(moments.len(), rows * nm, "moment buffer length mismatch");
        ColumnarBlock { orders, k, nm, rows, u, v, moments }
    }

    /// Re-encode the sketch panels as `q` (moments stay f64). Encoding
    /// happens exactly once, at the store boundary: callers only ever
    /// go f32 → quantized (ingest under a `panel-quant` setting) or
    /// quantized → f32 ([`ColumnarBlock::decode`]); chaining two lossy
    /// encodings would compound error and is never done.
    pub fn encoded_as(&self, q: PanelQuant) -> ColumnarBlock {
        if q == self.encoding() {
            return self.clone();
        }
        let panel_len = self.rows * self.k;
        let encode = |store: &PanelStore| {
            let mut flat = vec![0.0f32; self.orders * panel_len];
            for m in 0..self.orders {
                store.decode_into(m, m * panel_len, &mut flat[m * panel_len..(m + 1) * panel_len]);
            }
            PanelStore::encode(flat, q, self.orders, panel_len)
        };
        ColumnarBlock {
            orders: self.orders,
            k: self.k,
            nm: self.nm,
            rows: self.rows,
            u: encode(&self.u),
            v: self.v.as_ref().map(encode),
            moments: self.moments.clone(),
        }
    }

    /// Decode back to plain f32 panels. Exact: every quantized value
    /// maps to one f32, so `decode().encoded_as(q)` reproduces the
    /// original store bitwise.
    pub fn decode(&self) -> ColumnarBlock {
        self.encoded_as(PanelQuant::None)
    }

    /// Concatenate blocks covering consecutive row ranges into one
    /// block — the segment-compaction kernel. When every input shares
    /// one encoding (and, for i8, identical per-order scales), each
    /// (order, side) panel lands with a single contiguous copy at its
    /// row offset, so the merged block holds bitwise-identical encoded
    /// sketches. Otherwise the inputs are decoded to f32 first — decode
    /// is value-exact, so the merged block still holds exactly the
    /// values the estimators saw before compaction (zone summaries stay
    /// admissible either way). Moments always copy verbatim. Panics if
    /// the blocks disagree on shape/sidedness or if `blocks` is empty.
    pub fn concat(blocks: &[&ColumnarBlock]) -> ColumnarBlock {
        let first = blocks.first().expect("concat of zero blocks");
        let (orders, k, nm) = (first.orders, first.k, first.nm);
        let two_sided = first.is_two_sided();
        let rows: usize = blocks
            .iter()
            .map(|b| {
                assert_eq!(
                    (b.orders, b.k, b.nm, b.is_two_sided()),
                    (orders, k, nm, two_sided),
                    "heterogeneous blocks in concat"
                );
                b.rows
            })
            .sum();
        let u_parts: Vec<(&PanelStore, usize)> =
            blocks.iter().map(|b| (&b.u, b.rows)).collect();
        let u = PanelStore::concat_rows(&u_parts, orders, k);
        let v = if two_sided {
            let v_parts: Vec<(&PanelStore, usize)> = blocks
                .iter()
                .map(|b| (b.v.as_ref().expect("two-sided"), b.rows))
                .collect();
            match PanelStore::concat_rows(&v_parts, orders, k) {
                Some(v) => Some(Some(v)),
                None => None,
            }
        } else {
            Some(None)
        };
        let (u, v) = match (u, v) {
            (Some(u), Some(v)) => (u, v),
            _ => {
                // Mixed encodings (or unequal i8 scales): merge in the
                // exact f32 domain instead.
                let decoded: Vec<ColumnarBlock> = blocks.iter().map(|b| b.decode()).collect();
                let refs: Vec<&ColumnarBlock> = decoded.iter().collect();
                return ColumnarBlock::concat(&refs);
            }
        };
        let mut moments = vec![0.0f64; rows * nm];
        let mut r0 = 0usize;
        for b in blocks {
            moments[r0 * nm..(r0 + b.rows) * nm].copy_from_slice(&b.moments);
            r0 += b.rows;
        }
        ColumnarBlock { orders, k, nm, rows, u, v, moments }
    }

    /// Σ x^order of block row `r` (order >= 1).
    #[inline]
    pub fn moment(&self, r: usize, order: usize) -> f64 {
        self.moments_row(r)[order - 1]
    }

    /// Materialize block row `r` as a per-row [`RowSketch`] (the
    /// reference/AoS view — MLE queries and persistence use it).
    /// Quantized panels decode to their exact f32 values.
    pub fn to_row_sketch(&self, r: usize) -> RowSketch {
        assert!(r < self.rows, "block row {r} out of range ({})", self.rows);
        let mut uside = SketchSet::zeros(self.orders, self.k);
        for m in 1..=self.orders {
            self.u_view(m, r).decode_into(uside.u_mut(m));
        }
        let vside_data = self.v.as_ref().map(|_| {
            let mut s = SketchSet::zeros(self.orders, self.k);
            for m in 1..=self.orders {
                self.v_view(m, r).decode_into(s.u_mut(m));
            }
            s
        });
        RowSketch { uside, vside_data, moments: Moments(self.moments_row(r).to_vec()) }
    }

    /// Payload bytes (storage accounting, mirrors
    /// [`RowSketch::sketch_bytes`] summed over the block for f32 panels
    /// and shrinks with the panel encoding — i8 scales included).
    pub fn bytes(&self) -> usize {
        self.u.bytes()
            + self.v.as_ref().map_or(0, |v| v.bytes())
            + self.moments.len() * std::mem::size_of::<f64>()
    }
}

/// Split each order-major `n × k` panel of `buf` into per-worker row
/// bands: `result[w][m-1]` is worker `w`'s `counts[w] × k` slice of
/// order `m` — the disjoint mutable views the banded GEMM workers write.
fn split_order_bands<'a>(
    buf: &'a mut [f32],
    n: usize,
    k: usize,
    counts: &[usize],
) -> Vec<Vec<&'a mut [f32]>> {
    let mut bands: Vec<Vec<&'a mut [f32]>> = counts.iter().map(|_| Vec::new()).collect();
    for order_panel in buf.chunks_mut(n * k) {
        let mut rest = order_panel;
        for (w, &count) in counts.iter().enumerate() {
            let (head, tail) = rest.split_at_mut(count * k);
            bands[w].push(head);
            rest = tail;
        }
    }
    bands
}

/// One materialized chunk of every projection matrix (+ the sparse
/// representation when the distribution is mostly zeros).
struct Chunk {
    mats: Vec<ProjectionMatrix>,
    sparse: Option<Vec<SparseChunk>>,
}

/// Default memory budget for cached R chunks (estimated bytes). At the
/// default chunk = 2048 and k = 128 (basic strategy) one chunk is ~1 MiB,
/// so the budget covers D up to ~512k fully cached.
const CHUNK_CACHE_BUDGET_BYTES: usize = 256 << 20;

/// Chunk cache: each key maps to a once-cell so exactly one thread
/// materializes a chunk while concurrent requesters block on the cell —
/// not on the map lock, which is only held to look up / register keys.
///
/// Admission is budgeted, not evicting: chunks are cached first-come
/// until the byte budget is spent, and later chunks are materialized
/// uncached. For the pipeline's cyclic access pattern (every block walks
/// chunks 0..D/chunk in order) a pinned prefix keeps a `budget/total`
/// hit rate where LRU/FIFO eviction would degrade to zero hits the
/// moment one pass exceeds the capacity — and varying chunk sizes
/// (tests, reconfigured sketchers) still cannot grow the map without
/// bound.
#[derive(Debug, Default)]
struct ChunkCache {
    map: HashMap<(usize, usize), Arc<OnceLock<Arc<Chunk>>>>,
    /// Estimated bytes admitted so far.
    bytes: usize,
}

/// Sketching engine: owns the spec and chunking policy.
///
/// Materialized R chunks are cached (R is a pure function of the spec,
/// so blocks streaming through the pipeline reuse the same chunk instead
/// of re-running the counter-based sampler per block — EXPERIMENTS.md
/// §Perf iteration 2). The cache is keyed by chunk start and safe to
/// share across worker threads via `&self`: the entry-style once-cells
/// guarantee a chunk is materialized exactly once even under races, and
/// budgeted first-come admission ([`Sketcher::cache_budget`], no
/// eviction — see [`ChunkCache`]) bounds resident bytes even when chunk
/// sizes vary.
#[derive(Debug)]
pub struct Sketcher {
    pub spec: ProjectionSpec,
    pub p: usize,
    /// D-chunk size for materializing R (bounds memory at chunk × k × 4B
    /// per order-matrix).
    pub chunk: usize,
    /// Byte budget for the chunk cache (see [`ChunkCache`]); chunks past
    /// the budget are materialized uncached.
    pub cache_budget: usize,
    cache: Mutex<ChunkCache>,
}

impl Clone for Sketcher {
    fn clone(&self) -> Self {
        // The cache is a derived artifact; clones start cold.
        Sketcher {
            spec: self.spec.clone(),
            p: self.p,
            chunk: self.chunk,
            cache_budget: self.cache_budget,
            cache: Mutex::new(ChunkCache::default()),
        }
    }
}

impl std::fmt::Debug for Chunk {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Chunk({} mats)", self.mats.len())
    }
}

impl Sketcher {
    pub fn new(spec: ProjectionSpec, p: usize) -> Self {
        Sketcher {
            spec,
            p,
            chunk: 2048,
            cache_budget: CHUNK_CACHE_BUDGET_BYTES,
            cache: Mutex::new(ChunkCache::default()),
        }
    }

    /// Estimated resident bytes of one materialized chunk of `len` rows
    /// (dense matrices per order + the CSR mirror for sparse
    /// distributions), used for cache admission.
    fn chunk_bytes_estimate(&self, len: usize) -> usize {
        let mats = self.spec.matrix_count(self.orders());
        let dense = mats * len * self.spec.k * std::mem::size_of::<f32>();
        if self.spec.dist.sparsity() > 0.5 {
            // CSR offsets + (col, val) pairs; nonzeros ≤ dense entries.
            dense + dense / 2
        } else {
            dense
        }
    }

    fn materialize_chunk(&self, start: usize, len: usize) -> Arc<Chunk> {
        let n_mats = self.spec.matrix_count(self.orders());
        let mats: Vec<_> = (1..=n_mats).map(|id| self.spec.materialize(id, start, len)).collect();
        let sparse = (self.spec.dist.sparsity() > 0.5)
            .then(|| mats.iter().map(SparseChunk::from_dense).collect());
        Arc::new(Chunk { mats, sparse })
    }

    /// The materialized (and, budget permitting, cached) chunk
    /// `[start, start+len)`.
    ///
    /// A single critical section resolves the cache entry;
    /// materialization itself happens inside the entry's once-cell, so
    /// two workers racing on the same chunk never materialize it twice,
    /// and workers needing *different* chunks don't serialize behind
    /// each other's materialization. Past [`Sketcher::cache_budget`]
    /// chunks are materialized uncached (see [`ChunkCache`] for why the
    /// pinned prefix beats eviction here).
    fn chunk_at(&self, start: usize, len: usize) -> Arc<Chunk> {
        let admitted = {
            let mut cache = self.cache.lock().unwrap();
            match cache.map.get(&(start, len)) {
                Some(cell) => Some(cell.clone()),
                None => {
                    let est = self.chunk_bytes_estimate(len);
                    if cache.bytes + est <= self.cache_budget {
                        let cell: Arc<OnceLock<Arc<Chunk>>> = Arc::new(OnceLock::new());
                        cache.map.insert((start, len), cell.clone());
                        cache.bytes += est;
                        Some(cell)
                    } else {
                        None
                    }
                }
            }
        };
        match admitted {
            Some(cell) => cell.get_or_init(|| self.materialize_chunk(start, len)).clone(),
            None => self.materialize_chunk(start, len),
        }
    }

    /// Estimated bytes currently admitted to the chunk cache (test hook).
    #[cfg(test)]
    fn cached_bytes(&self) -> usize {
        self.cache.lock().unwrap().bytes
    }

    pub fn orders(&self) -> usize {
        self.p - 1
    }

    pub fn moment_orders(&self) -> usize {
        2 * (self.p - 1)
    }

    /// Sketch a batch of rows (slices of equal length D). R chunks are
    /// materialized once and shared across the whole batch — this is the
    /// fast path the pipeline workers use.
    pub fn sketch_rows(&self, rows: &[&[f32]]) -> Vec<RowSketch> {
        let k = self.spec.k;
        let orders = self.orders();
        let two_sided = matches!(self.spec.strategy, Strategy::Alternative);
        let d = rows.first().map_or(0, |r| r.len());
        let mut out: Vec<RowSketch> = rows
            .iter()
            .map(|r| {
                assert_eq!(r.len(), d, "ragged row batch");
                RowSketch {
                    uside: SketchSet::zeros(orders, k),
                    vside_data: two_sided.then(|| SketchSet::zeros(orders, k)),
                    moments: Moments(vec![0.0; self.moment_orders()]),
                }
            })
            .collect();

        let mut chunk_start = 0;
        while chunk_start < d {
            let rows_in_chunk = self.chunk.min(d - chunk_start);
            // Materialize (or fetch the cached) chunk of each matrix.
            // Sparse distributions (three-point with large s) carry a
            // CSR-like nonzero list so the axpy touches only nonzeros.
            let chunk = self.chunk_at(chunk_start, rows_in_chunk);
            self.accumulate_chunk(
                rows,
                chunk_start,
                rows_in_chunk,
                &chunk.mats,
                chunk.sparse.as_deref(),
                &mut out,
            );
            chunk_start += rows_in_chunk;
        }
        out
    }

    /// Sketch a single row.
    pub fn sketch_row(&self, row: &[f32]) -> RowSketch {
        self.sketch_rows(&[row]).pop().unwrap()
    }

    /// Sketch a batch of rows through the register-tiled GEMM path into
    /// a freshly allocated [`ColumnarBlock`] (arena layout). `workers`
    /// shards the batch row-band-wise via `std::thread::scope`; results
    /// are bitwise independent of the worker count.
    pub fn sketch_block(&self, rows: &[&[f32]], workers: usize) -> ColumnarBlock {
        let two_sided = matches!(self.spec.strategy, Strategy::Alternative);
        let mut out = ColumnarBlock::zeros(
            self.orders(),
            self.spec.k,
            self.moment_orders(),
            rows.len(),
            two_sided,
        );
        self.sketch_block_into(rows, workers, &mut out);
        out
    }

    /// GEMM-sketch `rows` into a caller-owned [`ColumnarBlock`]
    /// (overwritten, not accumulated). See [`super::gemm`] for the
    /// kernel structure; per D-chunk the data is power-expanded once and
    /// every order is projected from the same resident R chunk.
    ///
    /// Panics if `out`'s shape (rows, orders, k, moment orders,
    /// sidedness) disagrees with this sketcher / batch.
    pub fn sketch_block_into(&self, rows: &[&[f32]], workers: usize, out: &mut ColumnarBlock) {
        let n = rows.len();
        let orders = self.orders();
        let nm = self.moment_orders();
        let k = self.spec.k;
        let two_sided = matches!(self.spec.strategy, Strategy::Alternative);
        assert_eq!(out.rows, n, "block row count mismatch");
        assert_eq!(out.orders, orders, "block order count mismatch");
        assert_eq!(out.k, k, "block sketch width mismatch");
        assert_eq!(out.nm, nm, "block moment count mismatch");
        assert_eq!(out.v.is_some(), two_sided, "block sidedness mismatch");
        let (u_buf, mut v_buf, mom_buf) = out.f32_bufs_mut();
        u_buf.fill(0.0);
        if let Some(v) = v_buf.as_deref_mut() {
            v.fill(0.0);
        }
        mom_buf.fill(0.0);
        if n == 0 {
            return;
        }
        let d = rows[0].len();
        for r in rows {
            assert_eq!(r.len(), d, "ragged row batch");
        }
        if d == 0 {
            return;
        }
        // Route selection: a dense GEMM spends an FMA on every (entry,
        // order, lane) — zeros included. On mostly-zero data (sparse
        // term-frequency rows are the project's default workload) the
        // per-entry axpy route skips zero entries outright, which the
        // per-row baseline also does; matching it keeps the block path
        // a strict win on both dense and sparse data. The counting pass
        // is cheap next to sketching, and skipped entirely for sparse R
        // (its CSR kernel already skips zero powers per entry, so
        // `data_sparse` would never be consulted).
        let data_sparse = self.spec.dist.sparsity() <= 0.5 && {
            let nnz: usize = rows
                .iter()
                .map(|r| r.iter().filter(|&&x| x != 0.0).count())
                .sum();
            2 * nnz < n * d
        };
        let nw = workers.max(1).min(n);
        // Row bands, as even as possible (the first `rem` get one extra).
        let per = n / nw;
        let rem = n % nw;
        let counts: Vec<usize> = (0..nw).map(|w| per + usize::from(w < rem)).collect();
        let u_bands = split_order_bands(u_buf, n, k, &counts);
        let v_bands = v_buf.map(|v| split_order_bands(v, n, k, &counts));
        let mut mom_bands: Vec<&mut [f64]> = Vec::with_capacity(nw);
        {
            let mut rest: &mut [f64] = mom_buf;
            for &c in &counts {
                let (head, tail) = rest.split_at_mut(c * nm);
                mom_bands.push(head);
                rest = tail;
            }
        }
        if nw == 1 {
            let u = u_bands.into_iter().next().unwrap();
            let v = v_bands.map(|b| b.into_iter().next().unwrap());
            let m = mom_bands.into_iter().next().unwrap();
            self.sketch_band(rows, u, v, m, data_sparse);
            return;
        }
        std::thread::scope(|scope| {
            let mut v_iter = v_bands.map(|b| b.into_iter());
            let mut row0 = 0usize;
            for ((&count, u), m) in counts.iter().zip(u_bands).zip(mom_bands) {
                let band = &rows[row0..row0 + count];
                let v = v_iter.as_mut().map(|it| it.next().unwrap());
                scope.spawn(move || self.sketch_band(band, u, v, m, data_sparse));
                row0 += count;
            }
        });
    }

    /// Matrix index (into a [`Chunk`]'s `mats`) for matrix id `id`:
    /// the basic strategy shares one matrix, the alternative strategy
    /// materializes one per order.
    #[inline]
    fn mat_index(&self, id: usize) -> usize {
        match self.spec.strategy {
            Strategy::Basic => 0,
            Strategy::Alternative => id - 1,
        }
    }

    /// GEMM-sketch one contiguous row band: per D-chunk, expand the
    /// band's powers once, then one `P_m · R` product per (order, side).
    /// `u`/`v` hold one `band_rows × k` output panel per order.
    ///
    /// `data_sparse` routes mostly-zero data (with a dense R) through a
    /// per-entry axpy that skips zeros — the ladder is still computed
    /// once per entry, the output is still columnar, only the matmul
    /// shape changes. Sparse R ([`gemm::gemm_sparse`]) already skips
    /// zero powers per entry, so it keeps the GEMM route.
    fn sketch_band(
        &self,
        rows: &[&[f32]],
        mut u: Vec<&mut [f32]>,
        mut v: Option<Vec<&mut [f32]>>,
        moments: &mut [f64],
        data_sparse: bool,
    ) {
        let orders = self.orders();
        let nm = self.moment_orders();
        let k = self.spec.k;
        let br = rows.len();
        if br == 0 {
            return;
        }
        let d = rows[0].len();
        let mut powers = vec![0.0f32; orders * br * self.chunk.min(d)];
        let mut start = 0usize;
        while start < d {
            let cl = self.chunk.min(d - start);
            let chunk = self.chunk_at(start, cl);
            if data_sparse && chunk.sparse.is_none() {
                self.axpy_chunk_columnar(rows, start, cl, &chunk.mats, &mut u, &mut v, moments);
                start += cl;
                continue;
            }
            gemm::expand_powers(rows, start, cl, orders, nm, &mut powers, moments);
            for m in 1..=orders {
                let panel = &powers[(m - 1) * br * cl..m * br * cl];
                let ui = self.mat_index(m);
                match &chunk.sparse {
                    Some(sp) => {
                        gemm::gemm_sparse(&mut u[m - 1], panel, &sp[ui], start, br, cl, k);
                        if let Some(vb) = v.as_mut() {
                            let vi = self.mat_index(self.p - m);
                            gemm::gemm_sparse(&mut vb[m - 1], panel, &sp[vi], start, br, cl, k);
                        }
                    }
                    None => {
                        gemm::gemm(&mut u[m - 1], panel, &chunk.mats[ui].data, br, cl, k);
                        if let Some(vb) = v.as_mut() {
                            let vi = self.mat_index(self.p - m);
                            gemm::gemm(&mut vb[m - 1], panel, &chunk.mats[vi].data, br, cl, k);
                        }
                    }
                }
            }
            start += cl;
        }
    }

    /// Sparse-data route of [`Sketcher::sketch_band`]: for each nonzero
    /// entry, one f64 ladder + one k-wide axpy per (order, side) into
    /// the columnar panels. Per-(row, lane) accumulation runs in
    /// ascending feature order, so this route is also bitwise
    /// independent of the worker banding.
    #[allow(clippy::too_many_arguments)]
    fn axpy_chunk_columnar(
        &self,
        rows: &[&[f32]],
        start: usize,
        cl: usize,
        mats: &[ProjectionMatrix],
        u: &mut [&mut [f32]],
        v: &mut Option<Vec<&mut [f32]>>,
        moments: &mut [f64],
    ) {
        let orders = self.orders();
        let nm = self.moment_orders();
        let k = self.spec.k;
        let mut pw = vec![0.0f32; orders];
        for (r, row) in rows.iter().enumerate() {
            let mrow = &mut moments[r * nm..(r + 1) * nm];
            let off = r * k;
            for t in start..start + cl {
                let x = row[t];
                if x == 0.0 {
                    continue;
                }
                gemm::power_ladder_update(x, orders, mrow, &mut pw);
                for m in 1..=orders {
                    let urow = &mut u[m - 1][off..off + k];
                    axpy(urow, pw[m - 1], mats[self.mat_index(m)].row(t), k);
                    if let Some(vb) = v.as_mut() {
                        let vrow = &mut vb[m - 1][off..off + k];
                        axpy(vrow, pw[m - 1], mats[self.mat_index(self.p - m)].row(t), k);
                    }
                }
            }
        }
    }

    /// Accumulate one D-chunk for the whole batch.
    ///
    /// Loop order is `t` (feature) outer, batch row inner — each R row
    /// (k floats × orders) is loaded once per chunk step and reused
    /// across every batch row while it sits in L1. The row-outer layout
    /// re-streamed R per data row: ~`rows×` more R traffic, which made
    /// the sketch path memory-bound and killed worker scaling (see
    /// EXPERIMENTS.md §Perf, iteration 1).
    #[allow(clippy::too_many_arguments)]
    fn accumulate_chunk(
        &self,
        rows: &[&[f32]],
        start: usize,
        len: usize,
        mats: &[super::matrix::ProjectionMatrix],
        sparse: Option<&[SparseChunk]>,
        out: &mut [RowSketch],
    ) {
        let orders = self.orders();
        let k = self.spec.k;
        let shared = matches!(self.spec.strategy, Strategy::Basic);
        let mut powers = vec![0.0f32; orders];
        for t in start..start + len {
            for (row, rs) in rows.iter().zip(out.iter_mut()) {
                let x = row[t];
                if x == 0.0 {
                    continue; // zero data entry contributes nothing
                }
                // Hadamard power ladder x, x², … x^{2(p-1)}, walked in
                // f64 (shared with the GEMM paths): high-order moments
                // feeding `core/mle.rs` accumulate at full precision,
                // while the sketch powers stay the f32 casts of its
                // rungs.
                gemm::power_ladder_update(x, orders, &mut rs.moments.0, &mut powers);
                for (m, &pw) in (1..=orders).zip(powers.iter()) {
                    if shared {
                        match sparse {
                            Some(sp) => axpy_sparse(rs.uside.u_mut(m), pw, sp[0].row(t)),
                            None => axpy(rs.uside.u_mut(m), pw, mats[0].row(t), k),
                        }
                    } else {
                        // u-side order m: matrix id m; v-side order m: id p−m.
                        let vside = rs.vside_data.as_mut().unwrap();
                        match sparse {
                            Some(sp) => {
                                axpy_sparse(rs.uside.u_mut(m), pw, sp[m - 1].row(t));
                                axpy_sparse(vside.u_mut(m), pw, sp[self.p - m - 1].row(t));
                            }
                            None => {
                                axpy(rs.uside.u_mut(m), pw, mats[m - 1].row(t), k);
                                axpy(vside.u_mut(m), pw, mats[self.p - m - 1].row(t), k);
                            }
                        }
                    }
                }
            }
        }
    }
}

/// u += pw * r_row (dense).
#[inline]
fn axpy(u: &mut [f32], pw: f32, r_row: &[f32], k: usize) {
    for j in 0..k {
        u[j] += pw * r_row[j];
    }
}

/// u += pw * r_row over explicit nonzeros (sparse three-point path).
#[inline]
fn axpy_sparse(u: &mut [f32], pw: f32, nnz: &[(u32, f32)]) {
    for &(j, r) in nnz {
        u[j as usize] += pw * r;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::{ProjectionDist, Strategy};
    use crate::testkit;

    fn mk(strategy: Strategy, k: usize, p: usize) -> Sketcher {
        Sketcher::new(ProjectionSpec::new(7, k, ProjectionDist::Normal, strategy), p)
    }

    /// Naive dense u-side sketch for comparison.
    fn naive_uside(spec: &ProjectionSpec, p: usize, row: &[f32]) -> SketchSet {
        let orders = p - 1;
        let mut s = SketchSet::zeros(orders, spec.k);
        for m in 1..=orders {
            let id = match spec.strategy {
                Strategy::Basic => 1,
                Strategy::Alternative => m,
            };
            for (i, &x) in row.iter().enumerate() {
                let pw = (x as f64).powi(m as i32);
                for j in 0..spec.k {
                    s.u_mut(m)[j] += (pw * spec.entry(id, i as u64, j as u64)) as f32;
                }
            }
        }
        s
    }

    #[test]
    fn matches_naive_dense() {
        testkit::check(30, |g| {
            let strategy = if g.bool() { Strategy::Basic } else { Strategy::Alternative };
            let p = if g.bool() { 4 } else { 6 };
            let sk = mk(strategy, 8, p);
            let row = g.vec_f32(1..64, -1.0..1.0);
            let got = sk.sketch_row(&row);
            let want = naive_uside(&sk.spec, p, &row);
            for m in 1..p {
                for j in 0..8 {
                    let (a, b) = (got.uside.u(m)[j], want.u(m)[j]);
                    crate::prop_assert!(
                        (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                        "m={m} j={j}: {a} vs {b}"
                    );
                }
            }
        });
    }

    #[test]
    fn pair_sides_share_matrices() {
        // Alternative strategy invariant: the u-side of order m and the
        // v-side of order p−m are projections with the SAME matrix, so for
        // identical input rows they are identical vectors.
        let sk = mk(Strategy::Alternative, 8, 4);
        let row: Vec<f32> = (0..32).map(|i| 1.0 + (i as f32 * 0.3).sin()).collect();
        let rs = sk.sketch_row(&row);
        let v = rs.vside();
        // u-side order m uses id m; v-side order p−m uses id p−(p−m)=m.
        // With the same data powers they differ (x^m vs x^{p-m}) unless
        // m = p−m; check the shared-matrix property via order 2 (p=4).
        assert_eq!(rs.uside.u(2), v.u(2), "order p/2 must coincide");
        assert_ne!(rs.uside.u(1), v.u(1));
    }

    #[test]
    fn basic_strategy_single_sided() {
        let sk = mk(Strategy::Basic, 8, 4);
        let rs = sk.sketch_row(&[1.0, 2.0, 3.0]);
        assert!(rs.vside_data.is_none());
        assert_eq!(rs.vside(), &rs.uside);
    }

    #[test]
    fn chunking_invariant() {
        // Same sketch regardless of chunk size (linearity over D-chunks).
        testkit::check(20, |g| {
            let strategy = if g.bool() { Strategy::Basic } else { Strategy::Alternative };
            let mut sk = mk(strategy, 6, 4);
            let row = g.vec_f32(10..200, -1.0..1.0);
            sk.chunk = 1 + g.usize_in(0, 16);
            let a = sk.sketch_row(&row);
            sk.chunk = 4096;
            let b = sk.sketch_row(&row);
            for (x, y) in a.uside.data.iter().zip(&b.uside.data) {
                crate::prop_assert!((x - y).abs() < 1e-4 * (1.0 + y.abs()), "{x} vs {y}");
            }
            for (x, y) in a.vside().data.iter().zip(&b.vside().data) {
                crate::prop_assert!((x - y).abs() < 1e-4 * (1.0 + y.abs()), "vside");
            }
        });
    }

    #[test]
    fn merge_equals_concatenation() {
        testkit::check(20, |g| {
            let sk = mk(Strategy::Basic, 6, 4);
            let row = g.vec_f32(20..100, -1.0..1.0);
            let split = g.usize_in(1, row.len());
            let whole = sk.sketch_row(&row);
            let mut left_row = row.clone();
            left_row[split..].fill(0.0);
            let mut right_row = row.clone();
            right_row[..split].fill(0.0);
            let mut merged = sk.sketch_row(&left_row);
            merged.merge(&sk.sketch_row(&right_row));
            for (x, y) in merged.uside.data.iter().zip(&whole.uside.data) {
                crate::prop_assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "sketch merge");
            }
            for o in 1..=whole.moments.len() {
                crate::prop_assert!(
                    (merged.moments.get(o) - whole.moments.get(o)).abs()
                        < 1e-6 * (1.0 + whole.moments.get(o).abs()),
                    "moment {o}"
                );
            }
        });
    }

    #[test]
    fn moments_match_scan() {
        let sk = mk(Strategy::Basic, 4, 4);
        let row: Vec<f32> = vec![0.5, -0.25, 1.5, 0.0, 2.0];
        let rs = sk.sketch_row(&row);
        let want = Moments::scan_f32(&row, 6);
        for o in 1..=6 {
            assert!((rs.moments.get(o) - want.get(o)).abs() < 1e-9);
        }
    }

    #[test]
    fn sparse_three_point_same_semantics() {
        let spec = ProjectionSpec::new(3, 8, ProjectionDist::ThreePoint(16.0), Strategy::Basic);
        let sk = Sketcher::new(spec.clone(), 4);
        let row: Vec<f32> = (0..128).map(|i| ((i % 7) as f32 - 3.0) / 3.0).collect();
        let got = sk.sketch_row(&row);
        let want = naive_uside(&spec, 4, &row);
        for (a, b) in got.uside.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn batch_equals_individual() {
        let sk = mk(Strategy::Alternative, 5, 4);
        let r1: Vec<f32> = (0..50).map(|i| (i as f32 * 0.1).sin()).collect();
        let r2: Vec<f32> = (0..50).map(|i| (i as f32 * 0.2).cos()).collect();
        let batch = sk.sketch_rows(&[&r1, &r2]);
        let a = sk.sketch_row(&r1);
        let b = sk.sketch_row(&r2);
        assert_eq!(batch[0].uside.data, a.uside.data);
        assert_eq!(batch[1].uside.data, b.uside.data);
        assert_eq!(batch[1].vside().data, b.vside().data);
    }

    /// Shared comparison: GEMM block output vs the per-row reference,
    /// within relative f32 tolerance on sketches and tight f64 tolerance
    /// on moments.
    fn assert_block_matches_rows(sk: &Sketcher, got: &ColumnarBlock, want: &[RowSketch]) {
        assert_eq!(got.rows(), want.len());
        for (r, rs) in want.iter().enumerate() {
            for m in 1..=sk.orders() {
                for (j, (a, b)) in got.u_row(m, r).iter().zip(rs.uside.u(m)).enumerate() {
                    crate::prop_assert!(
                        (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
                        "u m={m} r={r} j={j}: {a} vs {b}"
                    );
                }
                for (j, (a, b)) in got.v_row(m, r).iter().zip(rs.vside().u(m)).enumerate() {
                    crate::prop_assert!(
                        (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
                        "v m={m} r={r} j={j}: {a} vs {b}"
                    );
                }
            }
            for o in 1..=sk.moment_orders() {
                let (a, b) = (got.moment(r, o), rs.moments.get(o));
                crate::prop_assert!(
                    (a - b).abs() <= 1e-9 * (1.0 + b.abs()),
                    "moment {o} r={r}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn gemm_block_matches_per_row_reference() {
        // Strategies × distributions × p ∈ {4, 6} × random (n, k, d,
        // chunk, workers) — n and k ranges deliberately straddle the
        // MR=4 / NR=8 tile edges.
        testkit::check(40, |g| {
            let strategy = if g.bool() { Strategy::Basic } else { Strategy::Alternative };
            let p = if g.bool() { 4 } else { 6 };
            let dist = match g.usize_in(0, 4) {
                0 => ProjectionDist::Normal,
                1 => ProjectionDist::Uniform,
                2 => ProjectionDist::ThreePoint(3.0),
                _ => ProjectionDist::ThreePoint(30.0),
            };
            let k = 1 + g.usize_in(0, 20);
            let n = 1 + g.usize_in(0, 13);
            let d = 1 + g.usize_in(0, 150);
            let mut sk = Sketcher::new(ProjectionSpec::new(11, k, dist, strategy), p);
            sk.chunk = 1 + g.usize_in(0, 64);
            let data: Vec<Vec<f32>> = (0..n).map(|_| g.vec_f32(d..d + 1, -2.0..2.0)).collect();
            let refs: Vec<&[f32]> = data.iter().map(|r| r.as_slice()).collect();
            let want = sk.sketch_rows(&refs);
            let workers = 1 + g.usize_in(0, 4);
            let got = sk.sketch_block(&refs, workers);
            assert_eq!(got.is_two_sided(), matches!(strategy, Strategy::Alternative));
            assert_block_matches_rows(&sk, &got, &want);
        });
    }

    #[test]
    fn gemm_block_tile_edges() {
        // Deterministic ragged shapes around the 4×8 micro-kernel.
        for &(n, k) in &[(1usize, 1usize), (3, 7), (4, 8), (5, 9), (6, 8), (4, 5), (9, 16)] {
            let sk = mk(Strategy::Basic, k, 4);
            let data: Vec<Vec<f32>> = (0..n)
                .map(|r| (0..100).map(|t| ((r * 53 + t) as f32 * 0.17).sin()).collect())
                .collect();
            let refs: Vec<&[f32]> = data.iter().map(|r| r.as_slice()).collect();
            let want = sk.sketch_rows(&refs);
            let got = sk.sketch_block(&refs, 3);
            assert_block_matches_rows(&sk, &got, &want);
        }
    }

    #[test]
    fn gemm_block_worker_count_invariant_bitwise() {
        // Banding only regroups rows into strips; every (row, lane)
        // accumulation sequence is fixed, so outputs are bitwise equal.
        for strategy in [Strategy::Basic, Strategy::Alternative] {
            let sk = mk(strategy, 13, 4);
            let data: Vec<Vec<f32>> = (0..11)
                .map(|r| (0..300).map(|t| ((r * 31 + t) as f32 * 0.07).cos()).collect())
                .collect();
            let refs: Vec<&[f32]> = data.iter().map(|r| r.as_slice()).collect();
            let base = sk.sketch_block(&refs, 1);
            for w in [2usize, 3, 5, 64] {
                assert_eq!(base, sk.sketch_block(&refs, w), "workers={w}");
            }
        }
    }

    #[test]
    fn gemm_block_chunk_size_invariant() {
        // Linearity over D-chunks: same sketches whatever the chunk size.
        testkit::check(15, |g| {
            let mut sk = mk(Strategy::Alternative, 6, 4);
            let row = g.vec_f32(30..200, -1.0..1.0);
            let refs: Vec<&[f32]> = vec![&row];
            sk.chunk = 1 + g.usize_in(0, 24);
            let a = sk.sketch_block(&refs, 1);
            sk.chunk = 4096;
            let b = sk.sketch_block(&refs, 1);
            for m in 1..=3 {
                for (x, y) in a.u_row(m, 0).iter().zip(b.u_row(m, 0)) {
                    crate::prop_assert!((x - y).abs() < 1e-4 * (1.0 + y.abs()), "{x} vs {y}");
                }
                for (x, y) in a.v_row(m, 0).iter().zip(b.v_row(m, 0)) {
                    crate::prop_assert!((x - y).abs() < 1e-4 * (1.0 + y.abs()), "vside");
                }
            }
        });
    }

    #[test]
    fn gemm_block_sparse_three_point() {
        // The CSR path must agree with the dense naive oracle.
        let spec = ProjectionSpec::new(3, 8, ProjectionDist::ThreePoint(16.0), Strategy::Basic);
        let sk = Sketcher::new(spec.clone(), 4);
        let row: Vec<f32> = (0..128).map(|i| ((i % 7) as f32 - 3.0) / 3.0).collect();
        let got = sk.sketch_block(&[&row], 2);
        let want = naive_uside(&spec, 4, &row);
        for m in 1..4 {
            for (a, b) in got.u_row(m, 0).iter().zip(want.u(m)) {
                assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "m={m}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn gemm_block_sparse_data_route() {
        // Mostly-zero rows with a dense R take the per-entry axpy route;
        // it must match the per-row reference and stay worker-invariant.
        for strategy in [Strategy::Basic, Strategy::Alternative] {
            let sk = mk(strategy, 9, 4);
            let data: Vec<Vec<f32>> = (0..6)
                .map(|r| {
                    (0..200)
                        .map(|t| {
                            if (r + t) % 10 == 0 {
                                ((r * 3 + t) as f32 * 0.13).sin()
                            } else {
                                0.0
                            }
                        })
                        .collect()
                })
                .collect();
            let refs: Vec<&[f32]> = data.iter().map(|x| x.as_slice()).collect();
            let want = sk.sketch_rows(&refs);
            let got = sk.sketch_block(&refs, 2);
            assert_block_matches_rows(&sk, &got, &want);
            assert_eq!(got, sk.sketch_block(&refs, 5));
        }
    }

    #[test]
    fn gemm_block_empty_and_zero_width() {
        let sk = mk(Strategy::Basic, 8, 4);
        let no_rows: [&[f32]; 0] = [];
        let empty = sk.sketch_block(&no_rows, 4);
        assert_eq!(empty.rows(), 0);
        assert_eq!(empty.bytes(), 0);
        let zero_width_rows: [&[f32]; 2] = [&[], &[]];
        let zero_width = sk.sketch_block(&zero_width_rows, 4);
        assert_eq!(zero_width.rows(), 2);
        assert!(zero_width.u_order(1).iter().all(|&x| x == 0.0));
        assert!(zero_width.moments_row(1).iter().all(|&m| m == 0.0));
    }

    #[test]
    fn block_into_reuses_buffer() {
        let sk = mk(Strategy::Basic, 8, 4);
        let r1: Vec<f32> = (0..40).map(|i| (i as f32 * 0.2).sin()).collect();
        let r2: Vec<f32> = (0..40).map(|i| (i as f32 * 0.3).cos()).collect();
        let mut buf = sk.sketch_block(&[&r1], 1);
        let direct = sk.sketch_block(&[&r2], 1);
        // Overwrite semantics: landing a new row erases the old content.
        sk.sketch_block_into(&[&r2], 1, &mut buf);
        assert_eq!(buf, direct);
    }

    #[test]
    fn to_row_sketch_round_trips() {
        for strategy in [Strategy::Basic, Strategy::Alternative] {
            let sk = mk(strategy, 8, 4);
            let rows: Vec<Vec<f32>> = (0..3)
                .map(|r| (0..32).map(|t| ((r + 2 * t) as f32 * 0.11).sin()).collect())
                .collect();
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            let block = sk.sketch_block(&refs, 1);
            for r in 0..3 {
                let rs = block.to_row_sketch(r);
                for m in 1..4 {
                    assert_eq!(rs.uside.u(m), block.u_row(m, r));
                    assert_eq!(rs.vside().u(m), block.v_row(m, r));
                }
                assert_eq!(rs.moments.0.as_slice(), block.moments_row(r));
                // Homogeneous rows: block bytes = Σ per-row bytes.
                assert_eq!(rs.sketch_bytes() * 3, block.bytes());
            }
        }
    }

    #[test]
    fn moments_accumulate_in_f64() {
        // |x| far from 1: by order 2(p−1) an f32 ladder visibly loses
        // precision; both CPU paths must match the f64 ladder of
        // `Moments::scan_f32` to full f64 accuracy.
        let row: Vec<f32> = (0..64).map(|i| 20.0 + (i as f32) * 0.37).collect();
        let sk = mk(Strategy::Basic, 4, 4);
        let want = Moments::scan_f32(&row, 6);
        let per_row = sk.sketch_row(&row);
        let block = sk.sketch_block(&[&row], 1);
        for o in 1..=6 {
            let w = want.get(o);
            assert!(
                (per_row.moments.get(o) - w).abs() <= 1e-12 * w.abs(),
                "per-row order {o}: {} vs {w}",
                per_row.moments.get(o)
            );
            assert!(
                (block.moment(0, o) - w).abs() <= 1e-12 * w.abs(),
                "block order {o}: {} vs {w}",
                block.moment(0, o)
            );
        }
    }

    #[test]
    fn chunk_cache_is_bounded() {
        // Varying chunk sizes used to grow the (start, len)-keyed map
        // without bound; budgeted admission keeps the estimated resident
        // bytes at or under the configured budget, while over-budget
        // chunks still materialize (uncached) with identical results.
        let mut sk = mk(Strategy::Basic, 4, 4);
        sk.cache_budget = 4 * sk.chunk_bytes_estimate(64);
        let row = vec![1.0f32; 600];
        let want = sk.sketch_row(&row);
        for chunk in (7..120).step_by(13) {
            sk.chunk = chunk;
            let got = sk.sketch_row(&row);
            for (a, b) in got.uside.data.iter().zip(&want.uside.data) {
                assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
            }
        }
        assert!(sk.cached_bytes() <= sk.cache_budget, "{}", sk.cached_bytes());
    }

    #[test]
    fn chunk_cache_concurrent_sketchers_agree() {
        // Entry-style cells: concurrent workers racing on a cold cache
        // still see exactly one materialization each and identical R.
        let sk = mk(Strategy::Alternative, 8, 4);
        let row: Vec<f32> = (0..256).map(|i| (i as f32 * 0.05).sin()).collect();
        let serial = sk.sketch_row(&row);
        let results: Vec<RowSketch> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    let (sk, row) = (&sk, &row);
                    scope.spawn(move || sk.sketch_row(row))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in results {
            assert_eq!(r.uside.data, serial.uside.data);
            assert_eq!(r.vside().data, serial.vside().data);
        }
    }

    #[test]
    fn storage_accounting() {
        let basic = mk(Strategy::Basic, 8, 4).sketch_row(&[1.0; 16]);
        let alt = mk(Strategy::Alternative, 8, 4).sketch_row(&[1.0; 16]);
        // alt pays 2× on the sketch payload (moments identical).
        let moments_bytes = 6 * 8;
        assert_eq!(
            alt.sketch_bytes() - moments_bytes,
            2 * (basic.sketch_bytes() - moments_bytes)
        );
    }

    #[test]
    fn quantized_blocks_round_trip_within_codec_error() {
        for strategy in [Strategy::Basic, Strategy::Alternative] {
            let sk = mk(strategy, 8, 4);
            let rows: Vec<Vec<f32>> = (0..5)
                .map(|r| (0..48).map(|t| ((r * 7 + 3 * t) as f32 * 0.17).sin() * 2.0).collect())
                .collect();
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            let block = sk.sketch_block(&refs, 1);
            let f32_panel_bytes = block.bytes() - block.moments_all().len() * 8;
            for q in [PanelQuant::F16, PanelQuant::Bf16, PanelQuant::I8] {
                let enc = block.encoded_as(q);
                assert_eq!(enc.encoding(), q);
                assert_eq!(enc.rows(), block.rows());
                assert_eq!(enc.is_two_sided(), block.is_two_sided());
                // ≥2× panel-byte reduction (i8 scale vectors included).
                let enc_panel_bytes = enc.bytes() - enc.moments_all().len() * 8;
                assert!(
                    2 * enc_panel_bytes <= f32_panel_bytes,
                    "{q:?}: {enc_panel_bytes} vs {f32_panel_bytes}"
                );
                // Moments are never quantized.
                assert_eq!(enc.moments_all(), block.moments_all());
                for r in 0..block.rows() {
                    let rs = enc.to_row_sketch(r);
                    for m in 1..4 {
                        for v_side in [false, true] {
                            let orig: Vec<f32> = if v_side {
                                block.v_row(m, r).to_vec()
                            } else {
                                block.u_row(m, r).to_vec()
                            };
                            let view = if v_side { enc.v_view(m, r) } else { enc.u_view(m, r) };
                            let scale_of = |b: &ColumnarBlock| {
                                let store = if v_side && b.is_two_sided() {
                                    b.v_store().unwrap()
                                } else {
                                    b.u_store()
                                };
                                store.i8_scales().map(|s| s[m - 1]).unwrap_or(0.0)
                            };
                            for (j, &x) in orig.iter().enumerate() {
                                let d = view.get(j);
                                let bound = match q {
                                    PanelQuant::None => 0.0,
                                    PanelQuant::F16 => x.abs() as f64 / 2048.0 + 2.0f64.powi(-24),
                                    PanelQuant::Bf16 => x.abs() as f64 / 256.0 + 1e-30,
                                    PanelQuant::I8 => scale_of(&enc) as f64 * 0.5 + 1e-12,
                                };
                                assert!(
                                    ((d - x) as f64).abs() <= bound,
                                    "{q:?} m={m} r={r} j={j} v={v_side}: {d} vs {x}"
                                );
                            }
                            // AoS export decodes to exactly the stored values.
                            let decoded: Vec<f32> = (0..8).map(|j| view.get(j)).collect();
                            let aos = if v_side { rs.vside().u(m) } else { rs.uside.u(m) };
                            assert_eq!(aos, decoded.as_slice());
                        }
                    }
                }
                // Decode is value-exact: re-encoding reproduces the store.
                let dec = enc.decode();
                assert_eq!(dec.encoding(), PanelQuant::None);
                assert_eq!(dec.encoded_as(q), enc);
            }
        }
    }

    #[test]
    fn concat_merges_homogeneous_encodings_and_decodes_mixed() {
        let sk = mk(Strategy::Alternative, 8, 4);
        let mk_block = |seed: usize, n: usize| {
            let rows: Vec<Vec<f32>> = (0..n)
                .map(|r| (0..40).map(|t| ((seed + 5 * r + 2 * t) as f32 * 0.19).sin()).collect())
                .collect();
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            sk.sketch_block(&refs, 1)
        };
        let (a, b) = (mk_block(1, 3), mk_block(100, 4));

        // Same encoding (f16): byte-concat; encoded rows land verbatim.
        let (qa, qb) = (a.encoded_as(PanelQuant::F16), b.encoded_as(PanelQuant::F16));
        let merged = ColumnarBlock::concat(&[&qa, &qb]);
        assert_eq!(merged.encoding(), PanelQuant::F16);
        assert_eq!(merged.rows(), 7);
        for r in 0..7 {
            let (src, sr) = if r < 3 { (&qa, r) } else { (&qb, r - 3) };
            for m in 1..4 {
                for j in 0..8 {
                    assert_eq!(merged.u_view(m, r).get(j), src.u_view(m, sr).get(j));
                    assert_eq!(merged.v_view(m, r).get(j), src.v_view(m, sr).get(j));
                }
            }
            assert_eq!(merged.moments_row(r), src.moments_row(sr));
        }

        // Mixed encodings: the merge happens in the exact f32 domain —
        // quantized inputs contribute their decoded values, f32 inputs
        // their originals, bitwise.
        let mixed = ColumnarBlock::concat(&[&qa, &b]);
        assert_eq!(mixed.encoding(), PanelQuant::None);
        for m in 1..4 {
            let want: Vec<f32> = (0..8).map(|j| qa.u_view(m, 1).get(j)).collect();
            assert_eq!(mixed.u_row(m, 1), want.as_slice());
            assert_eq!(mixed.u_row(m, 5), b.u_row(m, 2));
        }

        // i8 with unequal per-order scales cannot byte-concat (re-scaling
        // would change values): falls back to decoded f32.
        let (ia, ib) = (a.encoded_as(PanelQuant::I8), b.encoded_as(PanelQuant::I8));
        assert_ne!(
            ia.u_store().i8_scales(),
            ib.u_store().i8_scales(),
            "test premise: different data should give different scales"
        );
        let im = ColumnarBlock::concat(&[&ia, &ib]);
        assert_eq!(im.encoding(), PanelQuant::None);
        for m in 1..4 {
            let want: Vec<f32> = (0..8).map(|j| ib.u_view(m, 0).get(j)).collect();
            assert_eq!(im.u_row(m, 3), want.as_slice());
        }

        // Identical scales (same block twice) stay i8 end to end.
        let twice = ColumnarBlock::concat(&[&ia, &ia]);
        assert_eq!(twice.encoding(), PanelQuant::I8);
        assert_eq!(twice.rows(), 6);
        for j in 0..8 {
            assert_eq!(twice.u_view(2, 4).get(j), ia.u_view(2, 1).get(j));
        }
    }
}
