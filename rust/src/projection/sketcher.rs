//! Pure-rust power sketcher — the CPU mirror of the L1 Pallas kernel.
//!
//! Used (a) as the runtime fallback for shapes with no AOT artifact,
//! (b) as the reference in PJRT cross-checks, and (c) by the Monte-Carlo
//! experiments, which need millions of small sketches where PJRT dispatch
//! overhead would dominate.
//!
//! The layout mirrors the kernel exactly: one pass over x per D-chunk,
//! Hadamard power ladder in registers, all sketch orders updated from the
//! same resident R chunk. Sparse three-point distributions take a skip
//! path (zero entries never touch the accumulators).
//!
//! ## Sides (alternative strategy)
//!
//! Under the paper's alternative strategy (§2.2), each inner-product
//! *pair* shares one matrix: u₂&v₂ use R⁽ᵃ⁾, u₃&v₁ use R⁽ᵇ⁾, u₁&v₃ use
//! R⁽ᶜ⁾. So the left ("u") sketch of order m uses matrix id m while the
//! right ("v") sketch of order m uses matrix id p−m. Since every stored
//! row may appear on either side of a pair query, alternative-strategy
//! rows carry TWO sketch sets — a real 2× storage overhead over the
//! basic strategy that E2/E3 report alongside the variance comparison.
//! (Basic strategy: the sides coincide and only one set is stored.)

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::matrix::{ProjectionMatrix, ProjectionSpec};
use super::Strategy;
use crate::core::marginals::Moments;

/// Power sketches of one row for one side: `u(m)` is the k-vector
/// (x^∘m)ᵀ R^(id), m = 1..=orders.
#[derive(Clone, Debug, PartialEq)]
pub struct SketchSet {
    pub orders: usize,
    pub k: usize,
    /// Row-major (orders × k), f32 to match the PJRT artifacts.
    pub data: Vec<f32>,
}

impl SketchSet {
    pub fn zeros(orders: usize, k: usize) -> Self {
        SketchSet { orders, k, data: vec![0.0; orders * k] }
    }

    #[inline]
    pub fn u(&self, m: usize) -> &[f32] {
        debug_assert!(m >= 1 && m <= self.orders);
        &self.data[(m - 1) * self.k..m * self.k]
    }

    #[inline]
    pub fn u_mut(&mut self, m: usize) -> &mut [f32] {
        &mut self.data[(m - 1) * self.k..m * self.k]
    }

    /// ‖u(m)‖² in f64 (the MLE cubic needs it).
    pub fn norm2(&self, m: usize) -> f64 {
        self.u(m).iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Sketches are additive over D-chunks (linearity invariant).
    pub fn merge(&mut self, other: &SketchSet) {
        assert_eq!((self.orders, self.k), (other.orders, other.k));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }
}

/// Sketches + marginal moments of one row — everything the estimators
/// need, on both pair sides.
#[derive(Clone, Debug)]
pub struct RowSketch {
    /// Left-side sketches: order m projected with matrix id m.
    pub uside: SketchSet,
    /// Right-side sketches (alternative strategy only): order m projected
    /// with matrix id p−m. `None` ⇒ identical to `uside` (basic strategy).
    pub vside_data: Option<SketchSet>,
    /// Moments Σ x^m for m = 1..2(p-1), f64.
    pub moments: Moments,
}

impl RowSketch {
    /// The sketch set to use when this row is the *right* element of a
    /// pair query.
    #[inline]
    pub fn vside(&self) -> &SketchSet {
        self.vside_data.as_ref().unwrap_or(&self.uside)
    }

    /// Bytes of sketch payload (storage accounting for E7).
    pub fn sketch_bytes(&self) -> usize {
        let one = self.uside.data.len() * std::mem::size_of::<f32>();
        let sides = if self.vside_data.is_some() { 2 } else { 1 };
        one * sides + self.moments.0.len() * std::mem::size_of::<f64>()
    }

    pub fn merge(&mut self, other: &RowSketch) {
        self.uside.merge(&other.uside);
        match (&mut self.vside_data, &other.vside_data) {
            (Some(a), Some(b)) => a.merge(b),
            (None, None) => {}
            _ => panic!("cannot merge sketches of different strategies"),
        }
        self.moments.merge(&other.moments);
    }
}

/// One materialized chunk of every projection matrix (+ the sparse
/// representation when the distribution is mostly zeros).
struct Chunk {
    mats: Vec<ProjectionMatrix>,
    sparse: Option<Vec<SparseChunk>>,
}

/// Sketching engine: owns the spec and chunking policy.
///
/// Materialized R chunks are cached (R is a pure function of the spec,
/// so blocks streaming through the pipeline reuse the same chunk instead
/// of re-running the counter-based sampler per block — EXPERIMENTS.md
/// §Perf iteration 2). The cache is keyed by chunk start and safe to
/// share across worker threads via `&self`.
#[derive(Debug)]
pub struct Sketcher {
    pub spec: ProjectionSpec,
    pub p: usize,
    /// D-chunk size for materializing R (bounds memory at chunk × k × 4B
    /// per order-matrix).
    pub chunk: usize,
    cache: Mutex<HashMap<(usize, usize), Arc<Chunk>>>,
}

impl Clone for Sketcher {
    fn clone(&self) -> Self {
        // The cache is a derived artifact; clones start cold.
        Sketcher { spec: self.spec.clone(), p: self.p, chunk: self.chunk, cache: Mutex::new(HashMap::new()) }
    }
}

impl std::fmt::Debug for Chunk {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Chunk({} mats)", self.mats.len())
    }
}

impl Sketcher {
    pub fn new(spec: ProjectionSpec, p: usize) -> Self {
        Sketcher { spec, p, chunk: 2048, cache: Mutex::new(HashMap::new()) }
    }

    /// The materialized (and cached) chunk `[start, start+len)`.
    fn chunk_at(&self, start: usize, len: usize) -> Arc<Chunk> {
        if let Some(c) = self.cache.lock().unwrap().get(&(start, len)) {
            return c.clone();
        }
        let n_mats = self.spec.matrix_count(self.orders());
        let mats: Vec<_> = (1..=n_mats).map(|id| self.spec.materialize(id, start, len)).collect();
        let sparse = (self.spec.dist.sparsity() > 0.5)
            .then(|| mats.iter().map(SparseChunk::from_dense).collect());
        let chunk = Arc::new(Chunk { mats, sparse });
        self.cache.lock().unwrap().insert((start, len), chunk.clone());
        chunk
    }

    pub fn orders(&self) -> usize {
        self.p - 1
    }

    pub fn moment_orders(&self) -> usize {
        2 * (self.p - 1)
    }

    /// Sketch a batch of rows (slices of equal length D). R chunks are
    /// materialized once and shared across the whole batch — this is the
    /// fast path the pipeline workers use.
    pub fn sketch_rows(&self, rows: &[&[f32]]) -> Vec<RowSketch> {
        let k = self.spec.k;
        let orders = self.orders();
        let two_sided = matches!(self.spec.strategy, Strategy::Alternative);
        let d = rows.first().map_or(0, |r| r.len());
        let mut out: Vec<RowSketch> = rows
            .iter()
            .map(|r| {
                assert_eq!(r.len(), d, "ragged row batch");
                RowSketch {
                    uside: SketchSet::zeros(orders, k),
                    vside_data: two_sided.then(|| SketchSet::zeros(orders, k)),
                    moments: Moments(vec![0.0; self.moment_orders()]),
                }
            })
            .collect();

        let mut chunk_start = 0;
        while chunk_start < d {
            let rows_in_chunk = self.chunk.min(d - chunk_start);
            // Materialize (or fetch the cached) chunk of each matrix.
            // Sparse distributions (three-point with large s) carry a
            // CSR-like nonzero list so the axpy touches only nonzeros.
            let chunk = self.chunk_at(chunk_start, rows_in_chunk);
            self.accumulate_chunk(
                rows,
                chunk_start,
                rows_in_chunk,
                &chunk.mats,
                chunk.sparse.as_deref(),
                &mut out,
            );
            chunk_start += rows_in_chunk;
        }
        out
    }

    /// Sketch a single row.
    pub fn sketch_row(&self, row: &[f32]) -> RowSketch {
        self.sketch_rows(&[row]).pop().unwrap()
    }

    /// Accumulate one D-chunk for the whole batch.
    ///
    /// Loop order is `t` (feature) outer, batch row inner — each R row
    /// (k floats × orders) is loaded once per chunk step and reused
    /// across every batch row while it sits in L1. The row-outer layout
    /// re-streamed R per data row: ~`rows×` more R traffic, which made
    /// the sketch path memory-bound and killed worker scaling (see
    /// EXPERIMENTS.md §Perf, iteration 1).
    #[allow(clippy::too_many_arguments)]
    fn accumulate_chunk(
        &self,
        rows: &[&[f32]],
        start: usize,
        len: usize,
        mats: &[super::matrix::ProjectionMatrix],
        sparse: Option<&[SparseChunk]>,
        out: &mut [RowSketch],
    ) {
        let orders = self.orders();
        let nm = self.moment_orders();
        let k = self.spec.k;
        let shared = matches!(self.spec.strategy, Strategy::Basic);
        let mut powers = vec![0.0f32; nm];
        for t in start..start + len {
            for (row, rs) in rows.iter().zip(out.iter_mut()) {
                let x = row[t];
                if x == 0.0 {
                    continue; // zero data entry contributes nothing
                }
                // Hadamard power ladder x, x², … x^{2(p-1)}; moments always.
                let mut p = 1.0f32;
                for slot in powers.iter_mut() {
                    p *= x;
                    *slot = p;
                }
                for (m, &pw) in (1..=nm).zip(powers.iter()) {
                    rs.moments.0[m - 1] += pw as f64;
                    if m > orders {
                        continue;
                    }
                    if shared {
                        match sparse {
                            Some(sp) => axpy_sparse(rs.uside.u_mut(m), pw, sp[0].row(t)),
                            None => axpy(rs.uside.u_mut(m), pw, mats[0].row(t), k),
                        }
                    } else {
                        // u-side order m: matrix id m; v-side order m: id p−m.
                        let vside = rs.vside_data.as_mut().unwrap();
                        match sparse {
                            Some(sp) => {
                                axpy_sparse(rs.uside.u_mut(m), pw, sp[m - 1].row(t));
                                axpy_sparse(vside.u_mut(m), pw, sp[self.p - m - 1].row(t));
                            }
                            None => {
                                axpy(rs.uside.u_mut(m), pw, mats[m - 1].row(t), k);
                                axpy(vside.u_mut(m), pw, mats[self.p - m - 1].row(t), k);
                            }
                        }
                    }
                }
            }
        }
    }
}

/// CSR-like nonzero list of a materialized R chunk — built once per
/// chunk, shared across every row in the batch (the sparse three-point
/// distributions are 1−1/s zeros; touching only nonzeros is the paper's
/// §4 "sparsity speedup").
struct SparseChunk {
    row0: usize,
    /// Prefix offsets, len rows+1.
    offsets: Vec<u32>,
    /// (column, value) pairs of nonzeros, row-major.
    nnz: Vec<(u32, f32)>,
}

impl SparseChunk {
    fn from_dense(mat: &super::matrix::ProjectionMatrix) -> Self {
        let mut offsets = Vec::with_capacity(mat.rows + 1);
        let mut nnz = Vec::new();
        offsets.push(0u32);
        for i in 0..mat.rows {
            let row = &mat.data[i * mat.k..(i + 1) * mat.k];
            for (j, &r) in row.iter().enumerate() {
                if r != 0.0 {
                    nnz.push((j as u32, r));
                }
            }
            offsets.push(nnz.len() as u32);
        }
        SparseChunk { row0: mat.row0, offsets, nnz }
    }

    #[inline]
    fn row(&self, i: usize) -> &[(u32, f32)] {
        let r = i - self.row0;
        &self.nnz[self.offsets[r] as usize..self.offsets[r + 1] as usize]
    }
}

/// u += pw * r_row (dense).
#[inline]
fn axpy(u: &mut [f32], pw: f32, r_row: &[f32], k: usize) {
    for j in 0..k {
        u[j] += pw * r_row[j];
    }
}

/// u += pw * r_row over explicit nonzeros (sparse three-point path).
#[inline]
fn axpy_sparse(u: &mut [f32], pw: f32, nnz: &[(u32, f32)]) {
    for &(j, r) in nnz {
        u[j as usize] += pw * r;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::{ProjectionDist, Strategy};
    use crate::testkit;

    fn mk(strategy: Strategy, k: usize, p: usize) -> Sketcher {
        Sketcher::new(ProjectionSpec::new(7, k, ProjectionDist::Normal, strategy), p)
    }

    /// Naive dense u-side sketch for comparison.
    fn naive_uside(spec: &ProjectionSpec, p: usize, row: &[f32]) -> SketchSet {
        let orders = p - 1;
        let mut s = SketchSet::zeros(orders, spec.k);
        for m in 1..=orders {
            let id = match spec.strategy {
                Strategy::Basic => 1,
                Strategy::Alternative => m,
            };
            for (i, &x) in row.iter().enumerate() {
                let pw = (x as f64).powi(m as i32);
                for j in 0..spec.k {
                    s.u_mut(m)[j] += (pw * spec.entry(id, i as u64, j as u64)) as f32;
                }
            }
        }
        s
    }

    #[test]
    fn matches_naive_dense() {
        testkit::check(30, |g| {
            let strategy = if g.bool() { Strategy::Basic } else { Strategy::Alternative };
            let p = if g.bool() { 4 } else { 6 };
            let sk = mk(strategy, 8, p);
            let row = g.vec_f32(1..64, -1.0..1.0);
            let got = sk.sketch_row(&row);
            let want = naive_uside(&sk.spec, p, &row);
            for m in 1..p {
                for j in 0..8 {
                    let (a, b) = (got.uside.u(m)[j], want.u(m)[j]);
                    crate::prop_assert!(
                        (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                        "m={m} j={j}: {a} vs {b}"
                    );
                }
            }
        });
    }

    #[test]
    fn pair_sides_share_matrices() {
        // Alternative strategy invariant: the u-side of order m and the
        // v-side of order p−m are projections with the SAME matrix, so for
        // identical input rows they are identical vectors.
        let sk = mk(Strategy::Alternative, 8, 4);
        let row: Vec<f32> = (0..32).map(|i| 1.0 + (i as f32 * 0.3).sin()).collect();
        let rs = sk.sketch_row(&row);
        let v = rs.vside();
        // u-side order m uses id m; v-side order p−m uses id p−(p−m)=m.
        // With the same data powers they differ (x^m vs x^{p-m}) unless
        // m = p−m; check the shared-matrix property via order 2 (p=4).
        assert_eq!(rs.uside.u(2), v.u(2), "order p/2 must coincide");
        assert_ne!(rs.uside.u(1), v.u(1));
    }

    #[test]
    fn basic_strategy_single_sided() {
        let sk = mk(Strategy::Basic, 8, 4);
        let rs = sk.sketch_row(&[1.0, 2.0, 3.0]);
        assert!(rs.vside_data.is_none());
        assert_eq!(rs.vside(), &rs.uside);
    }

    #[test]
    fn chunking_invariant() {
        // Same sketch regardless of chunk size (linearity over D-chunks).
        testkit::check(20, |g| {
            let strategy = if g.bool() { Strategy::Basic } else { Strategy::Alternative };
            let mut sk = mk(strategy, 6, 4);
            let row = g.vec_f32(10..200, -1.0..1.0);
            sk.chunk = 1 + g.usize_in(0, 16);
            let a = sk.sketch_row(&row);
            sk.chunk = 4096;
            let b = sk.sketch_row(&row);
            for (x, y) in a.uside.data.iter().zip(&b.uside.data) {
                crate::prop_assert!((x - y).abs() < 1e-4 * (1.0 + y.abs()), "{x} vs {y}");
            }
            for (x, y) in a.vside().data.iter().zip(&b.vside().data) {
                crate::prop_assert!((x - y).abs() < 1e-4 * (1.0 + y.abs()), "vside");
            }
        });
    }

    #[test]
    fn merge_equals_concatenation() {
        testkit::check(20, |g| {
            let sk = mk(Strategy::Basic, 6, 4);
            let row = g.vec_f32(20..100, -1.0..1.0);
            let split = g.usize_in(1, row.len());
            let whole = sk.sketch_row(&row);
            let mut left_row = row.clone();
            left_row[split..].fill(0.0);
            let mut right_row = row.clone();
            right_row[..split].fill(0.0);
            let mut merged = sk.sketch_row(&left_row);
            merged.merge(&sk.sketch_row(&right_row));
            for (x, y) in merged.uside.data.iter().zip(&whole.uside.data) {
                crate::prop_assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "sketch merge");
            }
            for o in 1..=whole.moments.len() {
                crate::prop_assert!(
                    (merged.moments.get(o) - whole.moments.get(o)).abs()
                        < 1e-6 * (1.0 + whole.moments.get(o).abs()),
                    "moment {o}"
                );
            }
        });
    }

    #[test]
    fn moments_match_scan() {
        let sk = mk(Strategy::Basic, 4, 4);
        let row: Vec<f32> = vec![0.5, -0.25, 1.5, 0.0, 2.0];
        let rs = sk.sketch_row(&row);
        let want = Moments::scan_f32(&row, 6);
        for o in 1..=6 {
            assert!((rs.moments.get(o) - want.get(o)).abs() < 1e-9);
        }
    }

    #[test]
    fn sparse_three_point_same_semantics() {
        let spec = ProjectionSpec::new(3, 8, ProjectionDist::ThreePoint(16.0), Strategy::Basic);
        let sk = Sketcher::new(spec.clone(), 4);
        let row: Vec<f32> = (0..128).map(|i| ((i % 7) as f32 - 3.0) / 3.0).collect();
        let got = sk.sketch_row(&row);
        let want = naive_uside(&spec, 4, &row);
        for (a, b) in got.uside.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn batch_equals_individual() {
        let sk = mk(Strategy::Alternative, 5, 4);
        let r1: Vec<f32> = (0..50).map(|i| (i as f32 * 0.1).sin()).collect();
        let r2: Vec<f32> = (0..50).map(|i| (i as f32 * 0.2).cos()).collect();
        let batch = sk.sketch_rows(&[&r1, &r2]);
        let a = sk.sketch_row(&r1);
        let b = sk.sketch_row(&r2);
        assert_eq!(batch[0].uside.data, a.uside.data);
        assert_eq!(batch[1].uside.data, b.uside.data);
        assert_eq!(batch[1].vside().data, b.vside().data);
    }

    #[test]
    fn storage_accounting() {
        let basic = mk(Strategy::Basic, 8, 4).sketch_row(&[1.0; 16]);
        let alt = mk(Strategy::Alternative, 8, 4).sketch_row(&[1.0; 16]);
        // alt pays 2× on the sketch payload (moments identical).
        let moments_bytes = 6 * 8;
        assert_eq!(
            alt.sketch_bytes() - moments_bytes,
            2 * (basic.sketch_bytes() - moments_bytes)
        );
    }
}
