//! Projection-entry distributions (paper §2.1 and §4).
//!
//! All have mean 0, variance 1; they differ in the fourth moment
//! `s = E r⁴`, the only distribution parameter the variance formulas see
//! (Lemma 6). Supported:
//!
//! * `Normal` — N(0,1), s = 3 (§2).
//! * `Uniform` — U(−√3, √3), s = 9/5 (§4, "simpler than normal").
//! * `ThreePoint(s)` — Achlioptas-style sparse sub-Gaussian: ±√s with
//!   probability 1/(2s) each, 0 otherwise, s ≥ 1 (§4). s = 1 is the
//!   Rademacher ±1; s = 3 reproduces the classic 1/6–2/3–1/6 scheme;
//!   large s gives 1−1/s sparsity and a proportional sketching speedup.

use crate::util::normal::normal_at;
use crate::util::rng::{counter_hash, u64_to_f64};

const SQRT3: f64 = 1.732_050_807_568_877_2;

/// Entry distribution of the projection matrix R.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ProjectionDist {
    Normal,
    Uniform,
    ThreePoint(f64),
}

impl ProjectionDist {
    /// Fourth moment s = E r⁴ — the parameter of Lemma 6.
    pub fn kurtosis(&self) -> f64 {
        match self {
            ProjectionDist::Normal => 3.0,
            ProjectionDist::Uniform => 9.0 / 5.0,
            ProjectionDist::ThreePoint(s) => *s,
        }
    }

    /// Fraction of exactly-zero entries (sparsity exploited by the
    /// sketcher's skip path).
    pub fn sparsity(&self) -> f64 {
        match self {
            ProjectionDist::ThreePoint(s) => 1.0 - 1.0 / s,
            _ => 0.0,
        }
    }

    /// Entry value at lattice point `(i, j)` under `seed` — counter-based
    /// so R is random-access reproducible (chunked streaming, any order).
    #[inline]
    pub fn entry(&self, seed: u64, i: u64, j: u64) -> f64 {
        match self {
            ProjectionDist::Normal => normal_at(seed, i, j),
            ProjectionDist::Uniform => {
                let u = u64_to_f64(counter_hash(seed, i, j));
                (2.0 * u - 1.0) * SQRT3
            }
            ProjectionDist::ThreePoint(s) => {
                let u = u64_to_f64(counter_hash(seed, i, j));
                let half = 0.5 / s;
                if u < half {
                    s.sqrt()
                } else if u < 2.0 * half {
                    -s.sqrt()
                } else {
                    0.0
                }
            }
        }
    }

    pub fn parse(text: &str) -> anyhow::Result<Self> {
        match text {
            "normal" => Ok(ProjectionDist::Normal),
            "uniform" => Ok(ProjectionDist::Uniform),
            _ => {
                if let Some(sv) = text.strip_prefix("threepoint:") {
                    let s: f64 = sv.parse()?;
                    anyhow::ensure!(s >= 1.0, "three-point requires s >= 1, got {s}");
                    Ok(ProjectionDist::ThreePoint(s))
                } else {
                    anyhow::bail!("unknown distribution {text:?} (normal|uniform|threepoint:<s>)")
                }
            }
        }
    }

    pub fn describe(&self) -> String {
        match self {
            ProjectionDist::Normal => "normal".into(),
            ProjectionDist::Uniform => "uniform".into(),
            ProjectionDist::ThreePoint(s) => format!("threepoint:{s}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Welford;

    fn moments(dist: ProjectionDist, n: u64) -> (f64, f64, f64) {
        let mut w = Welford::new();
        let mut m4 = 0.0;
        for i in 0..n {
            let v = dist.entry(77, i, 5);
            w.push(v);
            m4 += v * v * v * v;
        }
        (w.mean(), w.variance(), m4 / n as f64)
    }

    #[test]
    fn normal_moments() {
        let (m, v, k) = moments(ProjectionDist::Normal, 200_000);
        assert!(m.abs() < 0.01 && (v - 1.0).abs() < 0.03 && (k - 3.0).abs() < 0.15);
    }

    #[test]
    fn uniform_moments() {
        let (m, v, k) = moments(ProjectionDist::Uniform, 200_000);
        assert!(m.abs() < 0.01, "mean {m}");
        assert!((v - 1.0).abs() < 0.01, "var {v}");
        assert!((k - 1.8).abs() < 0.02, "kurt {k} want 9/5");
    }

    #[test]
    fn three_point_moments_various_s() {
        for s in [1.0, 3.0, 10.0, 50.0] {
            let (m, v, k) = moments(ProjectionDist::ThreePoint(s), 400_000);
            assert!(m.abs() < 0.05 * s.sqrt(), "s={s} mean {m}");
            assert!((v - 1.0).abs() < 0.05, "s={s} var {v}");
            assert!((k - s).abs() < 0.15 * s, "s={s} kurt {k}");
        }
    }

    #[test]
    fn three_point_sparsity() {
        let s = 10.0;
        let d = ProjectionDist::ThreePoint(s);
        let zeros = (0..100_000)
            .filter(|&i| d.entry(3, i, 0) == 0.0)
            .count() as f64
            / 100_000.0;
        assert!((zeros - d.sparsity()).abs() < 0.01, "zeros {zeros}");
    }

    #[test]
    fn parse_roundtrip() {
        for text in ["normal", "uniform", "threepoint:4.5"] {
            let d = ProjectionDist::parse(text).unwrap();
            assert_eq!(d.describe(), text);
        }
        assert!(ProjectionDist::parse("threepoint:0.5").is_err());
        assert!(ProjectionDist::parse("cauchy").is_err());
    }
}
