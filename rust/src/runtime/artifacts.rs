//! Artifact manifest: what `make artifacts` produced and how to call it.
//!
//! The manifest is line-oriented `key=value` tokens (one artifact per
//! line) written by `python/compile/aot.py`; see that file's docstring.
//! Parsing it here keeps the rust side free of JSON dependencies.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// The operation an artifact implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Basic-strategy fused sketch: (x[b,d], r[d,k]) → (u[orders,b,k], m[moments,b]).
    Sketch,
    /// Alternative-strategy sketch: (x[b,d], r[orders,d,k]) → same outputs.
    SketchAlt,
    /// Pairwise combine: (u[orders,b,k], v[orders,b2,k], mx[b], my[b2]) → d̂[b,b2].
    Estimate,
    /// Exact pairwise l_p^p: (x[b,d], y[b2,d]) → d[b,b2].
    Exact,
}

impl OpKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "sketch" => OpKind::Sketch,
            "sketch_alt" => OpKind::SketchAlt,
            "estimate" => OpKind::Estimate,
            "exact" => OpKind::Exact,
            _ => anyhow::bail!("unknown artifact op {s:?}"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            OpKind::Sketch => "sketch",
            OpKind::SketchAlt => "sketch_alt",
            OpKind::Estimate => "estimate",
            OpKind::Exact => "exact",
        }
    }
}

/// One compiled-artifact description from the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub op: OpKind,
    pub p: usize,
    /// Row-block size (left operand).
    pub b: usize,
    /// Right-operand block size (estimate/exact only; == b otherwise).
    pub b2: usize,
    /// Feature width (sketch/exact only; 0 for estimate).
    pub d: usize,
    /// Sketch width (0 for exact).
    pub k: usize,
    /// Sketch orders p−1 (sketch/estimate).
    pub orders: usize,
    /// Moment orders 2(p−1) (sketch only).
    pub moments: usize,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
}

impl ArtifactMeta {
    fn from_line(line: &str) -> anyhow::Result<Self> {
        let mut kv = HashMap::new();
        for tok in line.split_whitespace() {
            let (key, value) = tok
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad manifest token {tok:?}"))?;
            kv.insert(key, value);
        }
        let get = |key: &str| -> anyhow::Result<&str> {
            kv.get(key)
                .copied()
                .ok_or_else(|| anyhow::anyhow!("manifest line missing {key}: {line:?}"))
        };
        let num = |key: &str| -> usize {
            kv.get(key).and_then(|v| v.parse().ok()).unwrap_or(0)
        };
        let op = OpKind::parse(get("op")?)?;
        let b = num("b");
        Ok(ArtifactMeta {
            name: get("name")?.to_string(),
            op,
            p: num("p"),
            b,
            b2: if kv.contains_key("b2") { num("b2") } else { b },
            d: num("d"),
            k: num("k"),
            orders: num("orders"),
            moments: num("moments"),
            file: get("file")?.to_string(),
        })
    }
}

/// Parsed manifest + artifact directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e} (run `make artifacts`)"))?;
        let mut artifacts = Vec::new();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            artifacts.push(ArtifactMeta::from_line(line)?);
        }
        anyhow::ensure!(!artifacts.is_empty(), "empty manifest {path:?}");
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Find the sketch artifact matching (op, p, k) exactly — block/d
    /// mismatches are handled by padding/chunking in the pipeline, but p
    /// and k change the math and must match.
    pub fn find_sketch(&self, op: OpKind, p: usize, k: usize) -> Option<&ArtifactMeta> {
        debug_assert!(matches!(op, OpKind::Sketch | OpKind::SketchAlt));
        self.artifacts
            .iter()
            .find(|a| a.op == op && a.p == p && a.k == k)
    }

    pub fn find_estimate(&self, p: usize, k: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.op == OpKind::Estimate && a.p == p && a.k == k)
    }

    pub fn find_exact(&self, p: usize) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.op == OpKind::Exact && a.p == p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_line() {
        let m = ArtifactMeta::from_line(
            "name=sketch_p4_b64_d1024_k64 op=sketch p=4 b=64 d=1024 k=64 orders=3 moments=6 file=f.hlo.txt",
        )
        .unwrap();
        assert_eq!(m.op, OpKind::Sketch);
        assert_eq!((m.p, m.b, m.d, m.k), (4, 64, 1024, 64));
        assert_eq!((m.orders, m.moments), (3, 6));
        assert_eq!(m.b2, 64, "b2 defaults to b");
    }

    #[test]
    fn estimate_line_has_b2() {
        let m = ArtifactMeta::from_line(
            "name=estimate_p4_b64_k64 op=estimate p=4 b=64 b2=32 k=64 orders=3 file=e.hlo.txt",
        )
        .unwrap();
        assert_eq!(m.op, OpKind::Estimate);
        assert_eq!(m.b2, 32);
        assert_eq!(m.d, 0);
    }

    #[test]
    fn missing_required_key_fails() {
        assert!(ArtifactMeta::from_line("op=sketch p=4 file=f").is_err());
        assert!(ArtifactMeta::from_line("name=x op=bogus file=f").is_err());
    }

    #[test]
    fn loads_repo_manifest_if_present() {
        // Integration smoke: if artifacts were built, the manifest parses
        // and paths resolve.
        let dir = Path::new("artifacts");
        if !dir.join("manifest.txt").exists() {
            return;
        }
        let m = Manifest::load(dir).unwrap();
        for a in &m.artifacts {
            assert!(m.hlo_path(a).exists(), "missing {:?}", a.file);
        }
        assert!(m.find_sketch(OpKind::Sketch, 4, 64).is_some());
    }
}
