//! PJRT engine actor: a dedicated thread owns the PJRT client and
//! compiled executables (raw PJRT handles are not `Send`), and serves
//! execution requests over channels.
//!
//! Cloneable [`EngineHandle`]s are handed to pipeline workers; the engine
//! thread exits when every handle is dropped. Compilation happens inside
//! the actor on first use (or eagerly via [`EngineHandle::warm`]), so the
//! request path only pays dispatch + execution.

use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use super::artifacts::Manifest;
use super::executor::{Executor, Input};

/// An owned input buffer + shape, sendable across the channel.
#[derive(Clone, Debug)]
pub struct OwnedInput {
    pub data: Vec<f32>,
    pub dims: Vec<usize>,
}

impl OwnedInput {
    pub fn new(data: Vec<f32>, dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        assert_eq!(data.len(), n, "input buffer/shape mismatch");
        OwnedInput { data, dims: dims.to_vec() }
    }
}

enum Job {
    Run {
        artifact: String,
        inputs: Vec<OwnedInput>,
        reply: mpsc::SyncSender<anyhow::Result<Vec<Vec<f32>>>>,
    },
    Warm {
        artifact: String,
        reply: mpsc::SyncSender<anyhow::Result<()>>,
    },
    /// Stop the actor (sent by `Engine::drop`; queued jobs before it are
    /// still served).
    Shutdown,
}

/// Cloneable handle to the engine actor.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Job>,
    manifest: Arc<Manifest>,
    platform: String,
}

impl EngineHandle {
    /// The parsed artifact manifest (shared, immutable).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }

    /// Execute `artifact` on `inputs`; blocks until the actor replies.
    pub fn run(&self, artifact: &str, inputs: Vec<OwnedInput>) -> anyhow::Result<Vec<Vec<f32>>> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Job::Run { artifact: artifact.to_string(), inputs, reply })
            .map_err(|_| anyhow::anyhow!("engine thread is gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine thread dropped the reply"))?
    }

    /// Compile `artifact` now (so later `run`s don't pay compile time).
    pub fn warm(&self, artifact: &str) -> anyhow::Result<()> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Job::Warm { artifact: artifact.to_string(), reply })
            .map_err(|_| anyhow::anyhow!("engine thread is gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine thread dropped the reply"))?
    }
}

/// The engine: spawns the actor thread and yields handles.
pub struct Engine {
    handle: EngineHandle,
    join: Option<JoinHandle<()>>,
}

impl Engine {
    /// Start the actor. Fails fast if the manifest is missing or the
    /// PJRT client cannot be created.
    pub fn start(artifacts_dir: &Path) -> anyhow::Result<Self> {
        let dir = artifacts_dir.to_path_buf();
        let (tx, rx) = mpsc::channel::<Job>();
        let (boot_tx, boot_rx) = mpsc::sync_channel::<anyhow::Result<(Arc<Manifest>, String)>>(1);
        let join = std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || {
                let mut executor = match Executor::new(&dir) {
                    Ok(ex) => {
                        let boot = (Arc::new(ex.manifest().clone()), ex.platform());
                        let _ = boot_tx.send(Ok(boot));
                        ex
                    }
                    Err(e) => {
                        let _ = boot_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::Run { artifact, inputs, reply } => {
                            let borrowed: Vec<Input<'_>> = inputs
                                .iter()
                                .map(|i| Input::new(&i.data, &i.dims))
                                .collect();
                            let _ = reply.send(executor.run(&artifact, &borrowed));
                        }
                        Job::Warm { artifact, reply } => {
                            let _ = reply.send(executor.warm(&artifact).map(|_| ()));
                        }
                        Job::Shutdown => break,
                    }
                }
            })?;
        let (manifest, platform) = boot_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))??;
        Ok(Engine { handle: EngineHandle { tx, manifest, platform }, join: Some(join) })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        // Explicit shutdown: jobs already queued are served, then the
        // actor exits and we join. Surviving handles see send errors.
        let _ = self.handle.tx.send(Job::Shutdown);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::fallback;
    use crate::util::rng::Rng;
    use std::path::PathBuf;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from("artifacts");
        dir.join("manifest.txt").exists().then_some(dir)
    }

    #[test]
    fn engine_runs_from_multiple_threads() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::start(&dir).unwrap();
        let meta = engine
            .handle()
            .manifest()
            .find_sketch(crate::runtime::OpKind::Sketch, 4, 64)
            .cloned();
        let Some(meta) = meta else { return };
        let (b, d, k, p) = (meta.b, meta.d, meta.k, meta.p);
        let mut threads = Vec::new();
        for t in 0..4u64 {
            let h = engine.handle();
            let name = meta.name.clone();
            threads.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                let x: Vec<f32> = (0..b * d).map(|_| (rng.next_f64() - 0.5) as f32).collect();
                let r: Vec<f32> = (0..d * k).map(|_| (rng.next_f64() - 0.5) as f32).collect();
                let outs = h
                    .run(
                        &name,
                        vec![
                            OwnedInput::new(x.clone(), &[b, d]),
                            OwnedInput::new(r.clone(), &[d, k]),
                        ],
                    )
                    .unwrap();
                let (u_want, _) = fallback::sketch_block(&x, &r, b, d, k, p);
                for (a, w) in outs[0].iter().zip(&u_want) {
                    assert!((a - w).abs() < 1e-2 * (1.0 + w.abs()));
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
    }

    #[test]
    fn engine_shuts_down_cleanly() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::start(&dir).unwrap();
        let h = engine.handle();
        drop(engine);
        // The surviving handle now points at a dead actor; calls error
        // rather than hang.
        assert!(h.warm("anything").is_err());
    }

    #[test]
    fn missing_dir_fails_fast() {
        assert!(Engine::start(Path::new("/definitely/not/here")).is_err());
    }
}
