//! Synchronous PJRT executor: loads HLO-text artifacts, compiles them on
//! the CPU PJRT client, caches the executables, and runs them on f32
//! buffers.
//!
//! Raw PJRT handles are not `Send`; this type is meant to be owned by a
//! single thread — the [`engine`](super::engine) actor wraps it behind
//! channels for the multi-threaded pipeline.

use std::collections::HashMap;
use std::path::Path;

use anyhow::Context;

use super::artifacts::{ArtifactMeta, Manifest};

/// An input buffer with its shape (row-major f32).
pub struct Input<'a> {
    pub data: &'a [f32],
    pub dims: Vec<i64>,
}

impl<'a> Input<'a> {
    pub fn new(data: &'a [f32], dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        assert_eq!(data.len(), n, "input buffer/shape mismatch");
        Input { data, dims: dims.iter().map(|&d| d as i64).collect() }
    }
}

/// Compiled-artifact cache over one PJRT client.
pub struct Executor {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Executor {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn new(dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Executor { client, manifest, compiled: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Ensure `name` is compiled; compiling is the expensive step
    /// (hundreds of ms) so the pipeline warms its artifacts up-front.
    pub fn warm(&mut self, name: &str) -> anyhow::Result<&ArtifactMeta> {
        let meta = self
            .manifest
            .by_name(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name:?} not in manifest"))?
            .clone();
        if !self.compiled.contains_key(name) {
            let path = self.manifest.hlo_path(&meta);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?;
            self.compiled.insert(name.to_string(), exe);
        }
        Ok(self.manifest.by_name(name).unwrap())
    }

    /// Execute artifact `name` on the given inputs; returns one flat f32
    /// buffer per output (artifacts are lowered with `return_tuple=True`,
    /// so the single result literal is a tuple we decompose).
    pub fn run(&mut self, name: &str, inputs: &[Input<'_>]) -> anyhow::Result<Vec<Vec<f32>>> {
        self.warm(name)?;
        let exe = self.compiled.get(name).unwrap();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|inp| {
                xla::Literal::vec1(inp.data)
                    .reshape(&inp.dims)
                    .with_context(|| format!("reshaping input to {:?}", inp.dims))
            })
            .collect::<anyhow::Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {name}"))?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple().context("decomposing output tuple")?;
        outs.into_iter()
            .map(|lit| lit.to_vec::<f32>().context("reading f32 output"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::fallback;
    use crate::util::rng::Rng;
    use std::path::PathBuf;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from("artifacts");
        dir.join("manifest.txt").exists().then_some(dir)
    }

    /// 3-way check (DESIGN.md §7): PJRT artifact output == pure-rust
    /// fallback (python ref is checked on the pytest side).
    #[test]
    fn pjrt_sketch_matches_fallback() {
        let Some(dir) = artifacts_dir() else { return };
        let mut ex = Executor::new(&dir).unwrap();
        let Some(meta) = ex.manifest().find_sketch(super::super::artifacts::OpKind::Sketch, 4, 64)
        else {
            return;
        };
        let (name, b, d, k, p) = (meta.name.clone(), meta.b, meta.d, meta.k, meta.p);
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..b * d).map(|_| (rng.next_f64() - 0.5) as f32).collect();
        let r: Vec<f32> = (0..d * k).map(|_| (rng.next_f64() - 0.5) as f32).collect();
        let outs = ex
            .run(&name, &[Input::new(&x, &[b, d]), Input::new(&r, &[d, k])])
            .unwrap();
        let (u_want, m_want) = fallback::sketch_block(&x, &r, b, d, k, p);
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].len(), u_want.len());
        for (a, w) in outs[0].iter().zip(&u_want) {
            assert!((a - w).abs() < 1e-2 * (1.0 + w.abs()), "u: {a} vs {w}");
        }
        for (a, w) in outs[1].iter().zip(&m_want) {
            assert!((a - w).abs() < 1e-2 * (1.0 + w.abs()), "m: {a} vs {w}");
        }
    }

    #[test]
    fn pjrt_estimate_matches_fallback() {
        let Some(dir) = artifacts_dir() else { return };
        let mut ex = Executor::new(&dir).unwrap();
        let Some(meta) = ex.manifest().find_estimate(4, 64) else { return };
        let (name, b, b2, k, p) = (meta.name.clone(), meta.b, meta.b2, meta.k, meta.p);
        let orders = p - 1;
        let mut rng = Rng::new(4);
        let u: Vec<f32> = (0..orders * b * k).map(|_| (rng.next_f64() - 0.5) as f32).collect();
        let v: Vec<f32> = (0..orders * b2 * k).map(|_| (rng.next_f64() - 0.5) as f32).collect();
        let mx: Vec<f32> = (0..b).map(|_| rng.next_f64() as f32).collect();
        let my: Vec<f32> = (0..b2).map(|_| rng.next_f64() as f32).collect();
        let outs = ex
            .run(
                &name,
                &[
                    Input::new(&u, &[orders, b, k]),
                    Input::new(&v, &[orders, b2, k]),
                    Input::new(&mx, &[b]),
                    Input::new(&my, &[b2]),
                ],
            )
            .unwrap();
        let want = fallback::estimate_block(&u, &v, &mx, &my, b, b2, k, p);
        assert_eq!(outs.len(), 1);
        for (a, w) in outs[0].iter().zip(&want) {
            assert!((a - w).abs() < 1e-2 * (1.0 + w.abs()), "{a} vs {w}");
        }
    }

    #[test]
    fn unknown_artifact_errors() {
        let Some(dir) = artifacts_dir() else { return };
        let mut ex = Executor::new(&dir).unwrap();
        assert!(ex.run("nope", &[]).is_err());
    }
}
