//! Pure-rust implementations of every artifact op, with the exact same
//! input/output layouts as the PJRT artifacts.
//!
//! Three jobs:
//! 1. runtime fallback when no artifact matches the requested shape,
//! 2. CPU baseline in the benchmarks,
//! 3. oracle in the PJRT cross-check tests (artifact output == fallback
//!    output == python ref, the 3-way invariant of DESIGN.md §7).
//!
//! Layouts (row-major, f32, matching `python/compile/model.py`):
//!   sketch:   x (B·D), r (D·K)           → u (orders·B·K), m (moments·B)
//!   sketch_alt: x (B·D), r (orders·D·K)  → same
//!   estimate: u (orders·B·K), v (orders·B2·K), mx (B), my (B2) → (B·B2)
//!   exact:    x (B·D), y (B2·D)          → (B·B2)

use crate::core::decompose::Decomposition;

/// Basic-strategy fused sketch (mirror of `kernels/sketch.py::sketch`).
///
/// Returns `(u, m)` with `u[(m-1)·B·K + i·K + j] = Σ_t x[i,t]^m r[t,j]`
/// and `m[(o-1)·B + i] = Σ_t x[i,t]^o`.
pub fn sketch_block(
    x: &[f32],
    r: &[f32],
    b: usize,
    d: usize,
    k: usize,
    p: usize,
) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(x.len(), b * d, "x shape");
    assert_eq!(r.len(), d * k, "r shape");
    let orders = p - 1;
    let n_mom = 2 * (p - 1);
    let mut u = vec![0.0f32; orders * b * k];
    let mut m = vec![0.0f32; n_mom * b];
    for i in 0..b {
        let row = &x[i * d..(i + 1) * d];
        for (t, &xv) in row.iter().enumerate() {
            let rrow = &r[t * k..(t + 1) * k];
            let mut pw = 1.0f32;
            for o in 1..=n_mom {
                pw *= xv;
                m[(o - 1) * b + i] += pw;
                if o <= orders && pw != 0.0 {
                    let dst = &mut u[((o - 1) * b + i) * k..((o - 1) * b + i + 1) * k];
                    for (uj, &rj) in dst.iter_mut().zip(rrow) {
                        *uj += pw * rj;
                    }
                }
            }
        }
    }
    (u, m)
}

/// Alternative-strategy fused sketch (`r_stack` is orders × D × K).
pub fn sketch_block_alt(
    x: &[f32],
    r_stack: &[f32],
    b: usize,
    d: usize,
    k: usize,
    p: usize,
) -> (Vec<f32>, Vec<f32>) {
    let orders = p - 1;
    assert_eq!(x.len(), b * d, "x shape");
    assert_eq!(r_stack.len(), orders * d * k, "r_stack shape");
    let n_mom = 2 * (p - 1);
    let mut u = vec![0.0f32; orders * b * k];
    let mut m = vec![0.0f32; n_mom * b];
    for i in 0..b {
        let row = &x[i * d..(i + 1) * d];
        for (t, &xv) in row.iter().enumerate() {
            let mut pw = 1.0f32;
            for o in 1..=n_mom {
                pw *= xv;
                m[(o - 1) * b + i] += pw;
                if o <= orders && pw != 0.0 {
                    let rrow = &r_stack[((o - 1) * d + t) * k..((o - 1) * d + t + 1) * k];
                    let dst = &mut u[((o - 1) * b + i) * k..((o - 1) * b + i + 1) * k];
                    for (uj, &rj) in dst.iter_mut().zip(rrow) {
                        *uj += pw * rj;
                    }
                }
            }
        }
    }
    (u, m)
}

/// Pairwise combine (`kernels/estimate.py` mirror): B×B2 estimate matrix.
pub fn estimate_block(
    u: &[f32],
    v: &[f32],
    mx_p: &[f32],
    my_p: &[f32],
    b: usize,
    b2: usize,
    k: usize,
    p: usize,
) -> Vec<f32> {
    let orders = p - 1;
    assert_eq!(u.len(), orders * b * k, "u shape");
    assert_eq!(v.len(), orders * b2 * k, "v shape");
    assert_eq!(mx_p.len(), b);
    assert_eq!(my_p.len(), b2);
    let dec = Decomposition::new(p).expect("valid p");
    let mut out = vec![0.0f32; b * b2];
    for i in 0..b {
        for j in 0..b2 {
            out[i * b2 + j] = mx_p[i] + my_p[j];
        }
    }
    // Accumulate c_m/k · U_m V_{p−m}ᵀ, matching the kernel's f32 order of
    // operations closely enough for the cross-check tolerances.
    for m in 1..p {
        let c = (dec.coeff(m) / k as f64) as f32;
        let um = &u[(m - 1) * b * k..m * b * k];
        let vn = &v[(p - m - 1) * b2 * k..(p - m) * b2 * k];
        for i in 0..b {
            let ui = &um[i * k..(i + 1) * k];
            for j in 0..b2 {
                let vj = &vn[j * k..(j + 1) * k];
                let mut dot = 0.0f32;
                for t in 0..k {
                    dot += ui[t] * vj[t];
                }
                out[i * b2 + j] += c * dot;
            }
        }
    }
    out
}

/// Exact pairwise l_p^p distances (`model.py::exact_block` mirror).
pub fn exact_block(x: &[f32], y: &[f32], b: usize, b2: usize, d: usize, p: usize) -> Vec<f32> {
    assert_eq!(x.len(), b * d, "x shape");
    assert_eq!(y.len(), b2 * d, "y shape");
    let half = (p / 2) as i32;
    let mut out = vec![0.0f32; b * b2];
    for i in 0..b {
        let xi = &x[i * d..(i + 1) * d];
        for j in 0..b2 {
            let yj = &y[j * d..(j + 1) * d];
            let mut acc = 0.0f32;
            for t in 0..d {
                let diff = xi[t] - yj[t];
                // |diff|^p == (diff²)^(p/2) for even p — no abs/powf.
                acc += (diff * diff).powi(half);
            }
            out[i * b2 + j] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::decompose::exact_distance;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect()
    }

    #[test]
    fn exact_block_matches_scalar() {
        let mut rng = Rng::new(1);
        let (b, b2, d, p) = (3, 4, 17, 4);
        let x = rand_vec(&mut rng, b * d);
        let y = rand_vec(&mut rng, b2 * d);
        let out = exact_block(&x, &y, b, b2, d, p);
        for i in 0..b {
            for j in 0..b2 {
                let xi: Vec<f64> = x[i * d..(i + 1) * d].iter().map(|&v| v as f64).collect();
                let yj: Vec<f64> = y[j * d..(j + 1) * d].iter().map(|&v| v as f64).collect();
                let want = exact_distance(&xi, &yj, p);
                let got = out[i * b2 + j] as f64;
                assert!((got - want).abs() < 1e-3 * (1.0 + want.abs()), "{got} vs {want}");
            }
        }
    }

    #[test]
    fn sketch_block_matches_sketcher() {
        // The fallback with an explicitly materialized R must agree with
        // the chunked Sketcher using the same spec.
        use crate::projection::sketcher::Sketcher;
        use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};
        let (b, d, k, p) = (4, 50, 8, 4);
        let spec = ProjectionSpec::new(11, k, ProjectionDist::Normal, Strategy::Basic);
        let mut rng = Rng::new(2);
        let x = rand_vec(&mut rng, b * d);
        // Materialize R the same way the pipeline feeds PJRT.
        let mat = spec.materialize(1, 0, d);
        let mut r = vec![0.0f32; d * k];
        for t in 0..d {
            r[t * k..(t + 1) * k].copy_from_slice(mat.row(t));
        }
        let (u, m) = sketch_block(&x, &r, b, d, k, p);
        let sk = Sketcher::new(spec, p);
        let rows: Vec<&[f32]> = (0..b).map(|i| &x[i * d..(i + 1) * d]).collect();
        let want = sk.sketch_rows(&rows);
        for i in 0..b {
            for ord in 1..p {
                for j in 0..k {
                    let got = u[((ord - 1) * b + i) * k + j];
                    let w = want[i].uside.u(ord)[j];
                    assert!((got - w).abs() < 2e-3 * (1.0 + w.abs()), "i={i} ord={ord} j={j}: {got} vs {w}");
                }
            }
            for o in 1..=2 * (p - 1) {
                let got = m[(o - 1) * b + i] as f64;
                let w = want[i].moments.get(o);
                assert!((got - w).abs() < 1e-2 * (1.0 + w.abs()), "moment {o}");
            }
        }
    }

    #[test]
    fn estimate_block_is_decomposition_combine() {
        // With exact powers as "sketches" of width k=1 scaled by k, the
        // estimate reduces to the exact decomposition identity.
        let (p, b) = (4usize, 2usize);
        let x = [0.5f32, -0.3];
        let y = [0.2f32, 0.7];
        // u_m[i] = x_i^m, v_m[j] = y_j^m with k=1: ⟨u_m, v_{p-m}⟩ = x^m y^{p-m}.
        let mut u = vec![0.0f32; (p - 1) * b];
        let mut v = vec![0.0f32; (p - 1) * b];
        for m in 1..p {
            for i in 0..b {
                u[(m - 1) * b + i] = x[i].powi(m as i32);
                v[(m - 1) * b + i] = y[i].powi(m as i32);
            }
        }
        let mx: Vec<f32> = x.iter().map(|v| v.powi(p as i32)).collect();
        let my: Vec<f32> = y.iter().map(|v| v.powi(p as i32)).collect();
        let out = estimate_block(&u, &v, &mx, &my, b, b, 1, p);
        for i in 0..b {
            for j in 0..b {
                let want = (x[i] - y[j]).powi(p as i32);
                let got = out[i * b + j];
                assert!((got - want).abs() < 1e-5, "{got} vs {want}");
            }
        }
    }

    #[test]
    fn alt_sketch_zero_row_gives_zero() {
        let (b, d, k, p) = (2, 10, 4, 4);
        let x = vec![0.0f32; b * d];
        let r = vec![1.0f32; (p - 1) * d * k];
        let (u, m) = sketch_block_alt(&x, &r, b, d, k, p);
        assert!(u.iter().all(|&v| v == 0.0));
        assert!(m.iter().all(|&v| v == 0.0));
    }
}
