//! Runtime layer: load and execute the AOT-compiled JAX/Pallas artifacts
//! from rust via PJRT, with a pure-rust fallback for arbitrary shapes.
//!
//! * [`artifacts`] — `manifest.txt` parsing (what `make artifacts` built).
//! * [`executor`] — single-threaded PJRT compile + execute cache.
//! * [`engine`] — actor thread wrapping the executor behind cloneable
//!   handles (raw PJRT handles are not `Send`).
//! * [`fallback`] — pure-rust mirror of every artifact op (shape-generic
//!   fallback, CPU baseline, and cross-check oracle).

pub mod artifacts;
pub mod engine;
pub mod executor;
pub mod fallback;

pub use artifacts::{ArtifactMeta, Manifest, OpKind};
pub use engine::{Engine, EngineHandle, OwnedInput};
