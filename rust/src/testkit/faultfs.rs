//! Fault-injecting [`DurableFs`] for crash-point testing: wraps the
//! real filesystem and fails specific operations at named sites — torn
//! record, short write, fsync failure, rename failure, disk-full — so
//! the durability tests can prove that every acknowledged batch
//! survives a crash at every point.
//!
//! A fault is addressed by (operation, path substring, nth match).
//! Actions model distinct real-world failures:
//!
//! * [`FaultAction::Err`] — one transient error; the op does not
//!   happen, later attempts succeed (an NFS hiccup, an EINTR'd fsync).
//! * [`FaultAction::ErrSticky`] — every matching op fails from then on
//!   (disk full, directory chmodded read-only).
//! * [`FaultAction::Torn`] — the write lands only partially on disk and
//!   the process "crashes" (kill -9 mid-write): the crash latch trips,
//!   failing every subsequent operation.
//! * [`FaultAction::CrashBefore`] — the process dies just before the
//!   op: nothing lands, the latch trips.
//!
//! Tests "restart" after a latched crash by recovering the same
//! directory with a clean [`RealFs`] — exactly what a real restart sees.

use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::coordinator::durable::{DurableFs, RealFs};
use crate::util::sync::MutexExt;

/// Which [`DurableFs`] operation a fault arms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultOp {
    ReadFile,
    WriteFile,
    AppendFile,
    SyncFile,
    SyncDir,
    Rename,
    RemoveFile,
    ListDir,
    CreateDirAll,
}

/// What happens when an armed fault's site is hit.
#[derive(Clone, Copy, Debug)]
pub enum FaultAction {
    /// One-shot transient error; the op is not performed.
    Err,
    /// Every matching op fails from the trigger on (disk-full style).
    ErrSticky,
    /// Write only the first `keep` bytes, then error and trip the crash
    /// latch. Only meaningful for `WriteFile` / `AppendFile`.
    Torn { keep: usize },
    /// Trip the crash latch before performing the op.
    CrashBefore,
}

/// One armed fault site.
#[derive(Clone, Debug)]
pub struct Fault {
    pub op: FaultOp,
    /// Trigger only on paths whose UTF-8 form contains this substring
    /// (empty = any path).
    pub path_contains: String,
    /// Skip this many matching calls before triggering (0 = first).
    pub skip: usize,
    pub action: FaultAction,
}

impl Fault {
    pub fn new(op: FaultOp, path_contains: &str, action: FaultAction) -> Self {
        Fault { op, path_contains: path_contains.to_string(), skip: 0, action }
    }

    pub fn after(mut self, skip: usize) -> Self {
        self.skip = skip;
        self
    }
}

struct Armed {
    fault: Fault,
    seen: usize,
    spent: bool,
}

/// What the gate decided for one op.
enum Gate {
    Proceed,
    Fail,
    Torn { keep: usize },
}

/// The fault-injecting filesystem. All real I/O is delegated to
/// [`RealFs`]; armed faults intercept matching calls.
pub struct FaultFs {
    inner: RealFs,
    armed: Mutex<Vec<Armed>>,
    crashed: AtomicBool,
}

impl FaultFs {
    pub fn new(faults: Vec<Fault>) -> Self {
        FaultFs {
            inner: RealFs,
            armed: Mutex::new(
                faults.into_iter().map(|fault| Armed { fault, seen: 0, spent: false }).collect(),
            ),
            crashed: AtomicBool::new(false),
        }
    }

    /// True once a `Torn` / `CrashBefore` fault tripped the latch; every
    /// operation after that fails, like a dead process's would.
    pub fn crashed(&self) -> bool {
        self.crashed.load(Ordering::SeqCst)
    }

    /// Arm another fault on a live instance.
    pub fn arm(&self, fault: Fault) {
        self.armed.lock_recover().push(Armed { fault, seen: 0, spent: false });
    }

    fn err(what: &str) -> io::Error {
        io::Error::new(io::ErrorKind::Other, format!("injected fault: {what}"))
    }

    fn gate(&self, op: FaultOp, path: &Path) -> io::Result<Gate> {
        if self.crashed() {
            return Err(Self::err("process crashed"));
        }
        let text = path.to_string_lossy();
        let mut armed = self.armed.lock_recover();
        for a in armed.iter_mut() {
            if a.fault.op != op || !text.contains(a.fault.path_contains.as_str()) {
                continue;
            }
            let hit = a.seen;
            a.seen += 1;
            if hit < a.fault.skip {
                continue;
            }
            match a.fault.action {
                FaultAction::Err => {
                    if a.spent {
                        continue;
                    }
                    a.spent = true;
                    return Ok(Gate::Fail);
                }
                FaultAction::ErrSticky => return Ok(Gate::Fail),
                FaultAction::Torn { keep } => {
                    if a.spent {
                        continue;
                    }
                    a.spent = true;
                    self.crashed.store(true, Ordering::SeqCst);
                    return Ok(Gate::Torn { keep });
                }
                FaultAction::CrashBefore => {
                    self.crashed.store(true, Ordering::SeqCst);
                    return Ok(Gate::Fail);
                }
            }
        }
        Ok(Gate::Proceed)
    }

    fn gate_simple(&self, op: FaultOp, path: &Path, what: &str) -> io::Result<()> {
        match self.gate(op, path)? {
            Gate::Proceed => Ok(()),
            Gate::Fail | Gate::Torn { .. } => Err(Self::err(what)),
        }
    }
}

impl DurableFs for FaultFs {
    fn read_file(&self, path: &Path) -> io::Result<Vec<u8>> {
        self.gate_simple(FaultOp::ReadFile, path, "read_file")?;
        self.inner.read_file(path)
    }

    fn write_file(&self, path: &Path, data: &[u8]) -> io::Result<()> {
        match self.gate(FaultOp::WriteFile, path)? {
            Gate::Proceed => self.inner.write_file(path, data),
            Gate::Fail => Err(Self::err("write_file")),
            Gate::Torn { keep } => {
                let keep = keep.min(data.len());
                self.inner.write_file(path, &data[..keep])?;
                Err(Self::err("write_file torn mid-write"))
            }
        }
    }

    fn append_file(&self, path: &Path, data: &[u8]) -> io::Result<()> {
        match self.gate(FaultOp::AppendFile, path)? {
            Gate::Proceed => self.inner.append_file(path, data),
            Gate::Fail => Err(Self::err("append_file")),
            Gate::Torn { keep } => {
                let keep = keep.min(data.len());
                self.inner.append_file(path, &data[..keep])?;
                Err(Self::err("append_file torn mid-write"))
            }
        }
    }

    fn sync_file(&self, path: &Path) -> io::Result<()> {
        self.gate_simple(FaultOp::SyncFile, path, "sync_file")?;
        self.inner.sync_file(path)
    }

    fn sync_dir(&self, path: &Path) -> io::Result<()> {
        self.gate_simple(FaultOp::SyncDir, path, "sync_dir")?;
        self.inner.sync_dir(path)
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        // Match on the destination: that's the name tests know (the
        // source is a `.tmp` sibling of it anyway).
        self.gate_simple(FaultOp::Rename, to, "rename")?;
        self.inner.rename(from, to)
    }

    fn remove_file(&self, path: &Path) -> io::Result<()> {
        self.gate_simple(FaultOp::RemoveFile, path, "remove_file")?;
        self.inner.remove_file(path)
    }

    fn list_dir(&self, dir: &Path) -> io::Result<Vec<PathBuf>> {
        self.gate_simple(FaultOp::ListDir, dir, "list_dir")?;
        self.inner.list_dir(dir)
    }

    fn create_dir_all(&self, path: &Path) -> io::Result<()> {
        self.gate_simple(FaultOp::CreateDirAll, path, "create_dir_all")?;
        self.inner.create_dir_all(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("lpsketch_faultfs_test")
            .join(format!("{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn transient_err_is_one_shot() {
        let dir = tmp_dir("oneshot");
        let fs = FaultFs::new(vec![Fault::new(FaultOp::WriteFile, "a.bin", FaultAction::Err)]);
        let p = dir.join("a.bin");
        assert!(fs.write_file(&p, b"x").is_err());
        assert!(!fs.crashed());
        assert!(fs.write_file(&p, b"x").is_ok(), "second attempt must succeed");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sticky_err_keeps_failing_and_spares_other_paths() {
        let dir = tmp_dir("sticky");
        let fs = FaultFs::new(vec![Fault::new(FaultOp::WriteFile, "full", FaultAction::ErrSticky)]);
        let p = dir.join("full.bin");
        for _ in 0..3 {
            assert!(fs.write_file(&p, b"x").is_err());
        }
        assert!(fs.write_file(&dir.join("other.bin"), b"x").is_ok());
        assert!(!fs.crashed());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_write_keeps_prefix_and_latches() {
        let dir = tmp_dir("torn");
        let fs =
            FaultFs::new(vec![Fault::new(FaultOp::AppendFile, "", FaultAction::Torn { keep: 3 })]);
        let p = dir.join("log.wal");
        assert!(fs.append_file(&p, b"hello").is_err());
        assert!(fs.crashed());
        assert_eq!(std::fs::read(&p).unwrap(), b"hel");
        // Everything after the crash fails, even unrelated ops.
        assert!(fs.read_file(&p).is_err());
        assert!(fs.sync_dir(&dir).is_err());
        // The bytes survive on disk for a clean-fs "restart".
        assert_eq!(RealFs.read_file(&p).unwrap(), b"hel");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn skip_counts_matching_calls_only() {
        let dir = tmp_dir("skip");
        let fs = FaultFs::new(vec![
            Fault::new(FaultOp::SyncFile, "b.bin", FaultAction::CrashBefore).after(1),
        ]);
        let a = dir.join("a.bin");
        let b = dir.join("b.bin");
        std::fs::write(&a, b"x").unwrap();
        std::fs::write(&b, b"x").unwrap();
        assert!(fs.sync_file(&b).is_ok(), "skip=1: first match passes");
        assert!(fs.sync_file(&a).is_ok(), "non-matching path never triggers");
        assert!(fs.sync_file(&b).is_err(), "second match crashes");
        assert!(fs.crashed());
        std::fs::remove_dir_all(&dir).ok();
    }
}
