//! Minimal property-testing support (`proptest` is not in the vendored
//! registry). Runs a closure over many seeded random cases and reports
//! the failing seed, so failures reproduce with `CASE_SEED=<n>`.
//!
//! ```ignore
//! testkit::check(200, |g| {
//!     let xs = g.vec_f64(1..100, 0.0..1.0);
//!     prop_assert(invariant(&xs), "invariant broke");
//! });
//! ```

use crate::util::rng::Rng;

pub mod faultfs;
pub mod store;

/// Case generator handed to property closures.
pub struct Gen {
    pub rng: Rng,
    pub case: u64,
}

impl Gen {
    /// Uniform usize in [lo, hi).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.rng.next_range(hi - lo)
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector with length in `len` range and entries in `val` range.
    pub fn vec_f64(
        &mut self,
        len: std::ops::Range<usize>,
        val: std::ops::Range<f64>,
    ) -> Vec<f64> {
        let n = self.usize_in(len.start, len.end);
        (0..n).map(|_| self.f64_in(val.start, val.end)).collect()
    }

    pub fn vec_f32(
        &mut self,
        len: std::ops::Range<usize>,
        val: std::ops::Range<f64>,
    ) -> Vec<f32> {
        self.vec_f64(len, val).into_iter().map(|v| v as f32).collect()
    }
}

/// Run `cases` random property cases. A failing case panics with its seed;
/// rerun just that case by setting `CASE_SEED`.
pub fn check<F: FnMut(&mut Gen)>(cases: u64, mut f: F) {
    if let Ok(s) = std::env::var("CASE_SEED") {
        let seed: u64 = s.parse().expect("CASE_SEED must be a u64");
        let mut g = Gen { rng: Rng::new(seed), case: seed };
        f(&mut g);
        return;
    }
    for case in 0..cases {
        let seed = 0xC0FFEE ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen { rng: Rng::new(seed), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut g)));
        if let Err(e) = result {
            eprintln!("property failed at case {case} (rerun with CASE_SEED={seed})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Assert with context, mirroring proptest's `prop_assert!`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            panic!("property violated: {}", format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_respect_ranges() {
        check(50, |g| {
            let n = g.usize_in(3, 10);
            assert!((3..10).contains(&n));
            let x = g.f64_in(-2.0, 5.0);
            assert!((-2.0..5.0).contains(&x));
            let v = g.vec_f64(1..20, 0.0..1.0);
            assert!(!v.is_empty() && v.len() < 20);
            assert!(v.iter().all(|&x| (0.0..1.0).contains(&x)));
        });
    }

    #[test]
    #[should_panic(expected = "property violated")]
    fn failing_property_panics() {
        check(10, |g| {
            let v = g.vec_f64(5..6, 0.0..1.0);
            prop_assert!(v.len() == 4, "len={}", v.len());
        });
    }
}
