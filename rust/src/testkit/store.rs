//! Random store populations for the columnar-lifecycle property suites:
//! map rows × segment blocks × ragged sizes straddling the kernels'
//! tile edges, p ∈ {4, 6}, one/two-sided. Generated once per case and
//! reused by the compaction-invariance, persistence-round-trip, and
//! segment-native-query tests, which all need the same two views of one
//! population: the mixed map+segment store under test and its all-map
//! per-row mirror (the reference path).

use crate::coordinator::SketchStore;
use crate::projection::sketcher::{ColumnarBlock, RowSketch, Sketcher};
use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};

use super::Gen;

/// One drawn population: the raw rows/blocks, so callers can
/// materialize as many stores (with any shard count) as a test needs.
pub struct StorePop {
    pub p: usize,
    pub k: usize,
    pub strategy: Strategy,
    /// Scattered per-row map entries (ids < 100).
    pub map_rows: Vec<(u64, RowSketch)>,
    /// Columnar segments `(base, block)`, base ascending, ranges
    /// disjoint and ≥ 100. Adjacency between consecutive blocks is
    /// randomized so compaction sees both mergeable runs and id gaps.
    pub blocks: Vec<(u64, ColumnarBlock)>,
}

impl StorePop {
    /// Materialize the population as a store: map rows in the shard
    /// maps, blocks as columnar segments.
    pub fn build(&self, shards: usize) -> SketchStore {
        let store = SketchStore::new(shards);
        for (id, rs) in &self.map_rows {
            store.insert(*id, rs.clone());
        }
        for (base, block) in &self.blocks {
            store.insert_block_columnar(*base, block.clone());
        }
        store
    }

    /// The per-row reference mirror: every row — including
    /// segment-resident ones — lands as a map entry, so queries take the
    /// map/snapshot paths end to end. Row payloads are bitwise-identical
    /// to [`StorePop::build`]'s (segment rows materialize through
    /// [`ColumnarBlock::to_row_sketch`], a verbatim copy).
    pub fn build_per_row(&self, shards: usize) -> SketchStore {
        let store = SketchStore::new(shards);
        for (id, rs) in &self.map_rows {
            store.insert(*id, rs.clone());
        }
        for (base, block) in &self.blocks {
            for r in 0..block.rows() {
                store.insert(base + r as u64, block.to_row_sketch(r));
            }
        }
        store
    }

    /// Every id in the population, ascending.
    pub fn ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.map_rows.iter().map(|(id, _)| *id).collect();
        for (base, block) in &self.blocks {
            ids.extend(*base..*base + block.rows() as u64);
        }
        ids.sort_unstable();
        ids
    }

    pub fn total_rows(&self) -> usize {
        self.map_rows.len() + self.blocks.iter().map(|(_, b)| b.rows()).sum::<usize>()
    }
}

/// Draw a random population. `map_rows_max = 0` forces a fully-columnar
/// store — the shape where the segment-native query paths engage.
pub fn random_store_pop(g: &mut Gen, map_rows_max: usize) -> StorePop {
    let p = if g.bool() { 4 } else { 6 };
    let strategy = if g.bool() { Strategy::Basic } else { Strategy::Alternative };
    // k straddles the 8-lane micro-kernel edge.
    let k = 1 + g.usize_in(0, 12);
    let d = 8 + g.usize_in(0, 24);
    let seed = g.usize_in(0, 1 << 16) as u64;
    let sk = Sketcher::new(ProjectionSpec::new(seed, k, ProjectionDist::Normal, strategy), p);
    let mut map_rows = Vec::new();
    if map_rows_max > 0 {
        let n_map = g.usize_in(0, map_rows_max + 1);
        let mut used = std::collections::BTreeSet::new();
        while used.len() < n_map {
            used.insert(g.usize_in(0, 50) as u64);
        }
        for id in used {
            let row = g.vec_f32(d..d + 1, -2.0..2.0);
            map_rows.push((id, sk.sketch_row(&row)));
        }
    }
    // Segment blocks: ragged sizes, sometimes straddling the
    // ARENA_TILE = 64 tile edge, sketched through the GEMM block path
    // with a random worker count (bitwise worker-invariant).
    let n_blocks = 1 + g.usize_in(0, 4);
    let mut base = 100u64;
    let mut blocks = Vec::new();
    for _ in 0..n_blocks {
        let rows = match g.usize_in(0, 6) {
            0 => 1,
            1 => 2 + g.usize_in(0, 6),
            2 => 63 + g.usize_in(0, 3), // 63 | 64 | 65
            _ => 3 + g.usize_in(0, 30),
        };
        let data: Vec<Vec<f32>> = (0..rows).map(|_| g.vec_f32(d..d + 1, -2.0..2.0)).collect();
        let refs: Vec<&[f32]> = data.iter().map(|r| r.as_slice()).collect();
        let block = sk.sketch_block(&refs, 1 + g.usize_in(0, 3));
        if g.bool() {
            // Gap: a compaction barrier between this block and the last.
            base += 1 + g.usize_in(0, 20) as u64;
        }
        blocks.push((base, block));
        base += rows as u64;
    }
    StorePop { p, k, strategy, map_rows, blocks }
}

/// Draw a fully-columnar population whose segments live at wildly
/// different magnitudes: per-block entry scales of 1×, 4×, 16×, 64×.
/// For p > 2 the marginal p-norm grows polynomially in the scale, so
/// the zone lower bounds of small-magnitude segments sit far below the
/// large-magnitude ones — the shape where pruned top-k provably skips
/// segments (the pruning-equivalence suite asserts it does).
pub fn skewed_store_pop(g: &mut Gen) -> StorePop {
    let p = if g.bool() { 4 } else { 6 };
    let strategy = if g.bool() { Strategy::Basic } else { Strategy::Alternative };
    let k = 1 + g.usize_in(0, 12);
    let d = 8 + g.usize_in(0, 24);
    let seed = g.usize_in(0, 1 << 16) as u64;
    let sk = Sketcher::new(ProjectionSpec::new(seed, k, ProjectionDist::Normal, strategy), p);
    // One block per magnitude band, shuffled order via random bases
    // being assigned in band order but with random gaps — bound-order
    // visiting must not depend on id order.
    let mut base = 100u64;
    let mut blocks = Vec::new();
    for &scale in &[1.0f32, 4.0, 16.0, 64.0] {
        let rows = 2 + g.usize_in(0, 12);
        let data: Vec<Vec<f32>> = (0..rows)
            .map(|_| g.vec_f32(d..d + 1, -2.0..2.0).iter().map(|x| x * scale).collect())
            .collect();
        let refs: Vec<&[f32]> = data.iter().map(|r| r.as_slice()).collect();
        let block = sk.sketch_block(&refs, 1 + g.usize_in(0, 3));
        if g.bool() {
            base += 1 + g.usize_in(0, 20) as u64;
        }
        blocks.push((base, block));
        base += rows as u64;
    }
    StorePop { p, k, strategy, map_rows: Vec::new(), blocks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn populations_are_well_formed() {
        testkit::check(20, |g| {
            let pop = random_store_pop(g, 4);
            let store = pop.build(3);
            let mirror = pop.build_per_row(2);
            assert_eq!(store.len(), pop.total_rows());
            assert_eq!(store.ids(), pop.ids());
            assert_eq!(mirror.ids(), pop.ids());
            assert_eq!(store.bytes(), mirror.bytes());
            assert!(store.segment_count() >= 1);
            assert_eq!(mirror.segment_count(), 0);
            // Row payloads identical across the two views.
            for &id in pop.ids().iter().take(5) {
                let a = store.get(id).unwrap();
                let b = mirror.get(id).unwrap();
                assert_eq!(a.uside.data, b.uside.data);
                assert_eq!(a.vside().data, b.vside().data);
                assert_eq!(a.moments.0, b.moments.0);
            }
        });
    }

    #[test]
    fn fully_columnar_populations_have_no_map_rows() {
        testkit::check(10, |g| {
            let pop = random_store_pop(g, 0);
            assert!(pop.map_rows.is_empty());
            let store = pop.build(2);
            assert!(store.map_ids().is_empty());
            assert_eq!(store.len(), pop.total_rows());
        });
    }

    #[test]
    fn skewed_populations_span_magnitude_bands() {
        testkit::check(10, |g| {
            let pop = skewed_store_pop(g);
            assert!(pop.map_rows.is_empty());
            assert_eq!(pop.blocks.len(), 4);
            let store = pop.build(2);
            assert_eq!(store.len(), pop.total_rows());
            // The largest band's max p-norm moment dwarfs the smallest
            // band's — the separation pruning feeds on.
            let zones = store.segments_snapshot_zoned();
            let pm = pop.p - 1; // index of moment order p in 0-based nm layout...
            let maxes: Vec<f64> = zones.iter().map(|(_, _, z)| z.max_moment[pm]).collect();
            let lo = maxes.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = maxes.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            assert!(hi > lo * 100.0, "bands must be separated (lo={lo}, hi={hi})");
        });
    }
}
