//! Shared utilities: PRNGs, normal sampling, streaming statistics,
//! poison-recovering lock acquisition.
pub mod normal;
pub mod rng;
pub mod stats;
pub mod sync;
