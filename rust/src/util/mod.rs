//! Shared utilities: PRNGs, normal sampling, streaming statistics.
pub mod normal;
pub mod rng;
pub mod stats;
