//! Standard-normal sampling: Box–Muller (sequential, with cached spare)
//! and a counter-based variant for random-access projection entries.

use super::rng::{counter_hash, u64_to_f64, Rng};

/// Sequential N(0,1) sampler wrapping [`Rng`]; caches the Box–Muller spare.
#[derive(Clone, Debug)]
pub struct NormalSampler {
    rng: Rng,
    spare: Option<f64>,
}

impl NormalSampler {
    pub fn new(seed: u64) -> Self {
        NormalSampler { rng: Rng::new(seed), spare: None }
    }

    pub fn from_rng(rng: Rng) -> Self {
        NormalSampler { rng, spare: None }
    }

    #[inline]
    pub fn sample(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        let (z0, z1) = box_muller(self.rng.next_f64_open(), self.rng.next_f64());
        self.spare = Some(z1);
        z0
    }

    pub fn fill(&mut self, out: &mut [f64]) {
        for o in out {
            *o = self.sample();
        }
    }
}

/// Classic Box–Muller: two uniforms -> two independent N(0,1).
/// `u0` must be in (0, 1]; `u1` in [0, 1).
#[inline]
pub fn box_muller(u0: f64, u1: f64) -> (f64, f64) {
    let r = (-2.0 * u0.ln()).sqrt();
    let theta = 2.0 * std::f64::consts::PI * u1;
    (r * theta.cos(), r * theta.sin())
}

/// Counter-based N(0,1): the value at lattice point `(a, b)` under `seed`.
/// Random access with no state — the basis of reproducible chunked
/// projection matrices (R entry (i, j) = `normal_at(seed, i, j)`).
#[inline]
pub fn normal_at(seed: u64, a: u64, b: u64) -> f64 {
    let h0 = counter_hash(seed, a, b);
    let h1 = counter_hash(seed ^ 0x6A09E667F3BCC909, a, b); // sqrt(2) bits
    let u0 = 1.0 - u64_to_f64(h0); // (0,1]
    let u1 = u64_to_f64(h1);
    box_muller(u0, u1).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Welford;

    #[test]
    fn sequential_moments() {
        let mut s = NormalSampler::new(11);
        let mut w = Welford::new();
        let mut kurt_acc = 0.0;
        let n = 200_000;
        for _ in 0..n {
            let z = s.sample();
            w.push(z);
            kurt_acc += z * z * z * z;
        }
        assert!(w.mean().abs() < 0.01, "mean={}", w.mean());
        assert!((w.variance() - 1.0).abs() < 0.02, "var={}", w.variance());
        // E z^4 = 3 for a standard normal — the constant Lemma 1 relies on.
        let k = kurt_acc / n as f64;
        assert!((k - 3.0).abs() < 0.1, "kurtosis={k}");
    }

    #[test]
    fn counter_based_moments_and_determinism() {
        let n = 100_000u64;
        let mut w = Welford::new();
        for i in 0..n {
            w.push(normal_at(5, i, 3));
        }
        assert!(w.mean().abs() < 0.02);
        assert!((w.variance() - 1.0).abs() < 0.03);
        assert_eq!(normal_at(5, 17, 3), normal_at(5, 17, 3));
        assert_ne!(normal_at(5, 17, 3), normal_at(6, 17, 3));
    }

    #[test]
    fn lattice_columns_uncorrelated() {
        let n = 50_000u64;
        let (mut sxy, mut sx, mut sy) = (0.0, 0.0, 0.0);
        for i in 0..n {
            let x = normal_at(2, i, 0);
            let y = normal_at(2, i, 1);
            sxy += x * y;
            sx += x;
            sy += y;
        }
        let nf = n as f64;
        let cov = sxy / nf - (sx / nf) * (sy / nf);
        assert!(cov.abs() < 0.02, "cov={cov}");
    }
}
