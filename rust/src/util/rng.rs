//! Deterministic PRNGs (no `rand` crate in the vendored registry).
//!
//! Two generators:
//!
//! * [`Rng`] — xoshiro256++ for sequential streams (fast, 2^256 period),
//!   seeded through SplitMix64 so any u64 seed yields a well-mixed state.
//! * [`counter_hash`] — a stateless SplitMix64-style mixer used as a
//!   counter-based RNG: projection-matrix entries are derived from
//!   `(seed, row, col)` so R never needs to be materialized or generated
//!   in a fixed order. This is what makes D-chunked / out-of-order
//!   streaming sketches reproducible (DESIGN.md §7 linearity invariant).

/// SplitMix64 step — also the core of [`counter_hash`].
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Stateless mix of up to three words; uniform over u64 for distinct inputs.
#[inline]
pub fn counter_hash(seed: u64, a: u64, b: u64) -> u64 {
    // Feed the words through sequential SplitMix64 rounds; the final
    // output is the third round's value, which passes PractRand-smoke
    // level independence for lattice inputs (tested in `tests` below).
    let mut s = seed ^ 0x243F6A8885A308D3; // pi
    let _ = splitmix64(&mut s);
    s ^= a.wrapping_mul(0x9E3779B97F4A7C15);
    let _ = splitmix64(&mut s);
    s ^= b.wrapping_mul(0xD1B54A32D192ED03);
    splitmix64(&mut s)
}

/// xoshiro256++ — the crate's general-purpose sequential PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so low-entropy seeds (0, 1, 2…) still give
    /// fully mixed states.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. per worker / per order).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ counter_hash(tag, 0x5EED, tag))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of mantissa.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe as a log() argument.
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        1.0 - self.next_f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn next_range(&mut self, n: usize) -> usize {
        // Lemire's multiply-shift rejection-free variant is fine here:
        // modulo bias at n << 2^64 is far below statistical noise.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_range(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Map a hashed u64 to uniform [0,1).
#[inline]
pub fn u64_to_f64(x: u64) -> f64 {
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let c: Vec<u64> = {
            let mut r = Rng::new(43);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_f64();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 3e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 3e-3, "var={var}");
    }

    #[test]
    fn counter_hash_decorrelated_on_lattice() {
        // Correlation between adjacent (row, col) lattice points must be tiny.
        let n = 50_000u64;
        let (mut sx, mut sy, mut sxy, mut sx2, mut sy2) = (0.0, 0.0, 0.0, 0.0, 0.0);
        for i in 0..n {
            let x = u64_to_f64(counter_hash(1, i, 0));
            let y = u64_to_f64(counter_hash(1, i + 1, 0));
            sx += x;
            sy += y;
            sxy += x * y;
            sx2 += x * x;
            sy2 += y * y;
        }
        let nf = n as f64;
        let cov = sxy / nf - (sx / nf) * (sy / nf);
        let corr = cov / ((sx2 / nf - (sx / nf).powi(2)).sqrt() * (sy2 / nf - (sy / nf).powi(2)).sqrt());
        assert!(corr.abs() < 0.02, "corr={corr}");
    }

    #[test]
    fn next_range_in_bounds_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
