//! Streaming statistics used by the Monte-Carlo experiments and the
//! bench harness: Welford mean/variance, percentiles, and a z-test for
//! the unbiasedness checks.

/// Welford online mean/variance (numerically stable).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (MC experiments divide by n; the estimators'
    /// theoretical Var is an exact population quantity).
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Unbiased sample variance.
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    ///
    /// Convention: with fewer than two samples the SEM is undefined and
    /// this returns `+∞` (no evidence about the spread yet) — it never
    /// returns NaN. (The seed version returned `sqrt(0/1) = 0` at n = 1,
    /// which made `z_against` blow up to ±∞ on a single sample.)
    pub fn sem(&self) -> f64 {
        if self.n < 2 {
            f64::INFINITY
        } else {
            (self.sample_variance() / self.n as f64).sqrt()
        }
    }

    /// z statistic for H0: E[x] == mu0. |z| < ~3 accepts at MC scale.
    ///
    /// Convention, guarded so the result is never NaN:
    /// * n < 2 — no evidence either way: returns 0.
    /// * zero sample variance — returns 0 when the mean equals `mu0`
    ///   exactly, ±∞ otherwise (a degenerate sample is infinitely
    ///   inconsistent with any other mean).
    pub fn z_against(&self, mu0: f64) -> f64 {
        let diff = self.mean - mu0;
        if self.n < 2 {
            return 0.0;
        }
        let sem = self.sem();
        if sem == 0.0 {
            return if diff == 0.0 { 0.0 } else { f64::INFINITY.copysign(diff) };
        }
        diff / sem
    }
}

/// Percentile of a sample (linear interpolation); `q` in [0, 1].
/// The input must already be sorted ascending (checked in debug builds).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    debug_assert!(
        sorted.windows(2).all(|w| w[0] <= w[1]),
        "percentile input must be sorted ascending"
    );
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Relative error |a - b| / max(|b|, eps).
#[inline]
pub fn rel_err(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-300)
}

/// Summary of a sample: mean, sd, p50, p95.
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub sd: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty());
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut w = Welford::new();
    for &x in xs {
        w.push(x);
    }
    Summary {
        n: xs.len(),
        mean: w.mean(),
        sd: w.sample_variance().sqrt(),
        p50: percentile(&sorted, 0.5),
        p95: percentile(&sorted, 0.95),
        min: sorted[0],
        max: *sorted.last().unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.5, -3.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean: f64 = xs.iter().sum::<f64>() / xs.len() as f64;
        let var: f64 = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn percentile_endpoints_and_median() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert!((percentile(&xs, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn z_test_calibration() {
        // Sample mean of U(0,1) should accept mu0=0.5 and reject mu0=0.6.
        let mut rng = crate::util::rng::Rng::new(1);
        let mut w = Welford::new();
        for _ in 0..10_000 {
            w.push(rng.next_f64());
        }
        assert!(w.z_against(0.5).abs() < 4.0);
        assert!(w.z_against(0.6).abs() > 10.0);
    }

    #[test]
    fn degenerate_samples_never_yield_nan() {
        // n = 0 and n = 1: undefined SEM → ∞, z → 0 (no evidence).
        let w = Welford::new();
        assert_eq!(w.sem(), f64::INFINITY);
        assert_eq!(w.z_against(3.0), 0.0);
        let mut w = Welford::new();
        w.push(1.5);
        assert_eq!(w.sem(), f64::INFINITY);
        assert_eq!(w.z_against(0.0), 0.0);
        assert!(!w.z_against(1.5).is_nan());
        // Zero variance at n >= 2: exact match → 0, mismatch → ±∞.
        let mut w = Welford::new();
        w.push(2.0);
        w.push(2.0);
        assert_eq!(w.z_against(2.0), 0.0);
        assert_eq!(w.z_against(1.0), f64::INFINITY);
        assert_eq!(w.z_against(3.0), f64::NEG_INFINITY);
    }

    #[test]
    fn summary_fields() {
        let s = summarize(&[3.0, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
    }
}
