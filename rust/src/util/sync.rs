//! Poison-recovering lock acquisition.
//!
//! Every critical section in the store/pipeline upholds its invariants
//! at each intermediate point (epoch bumps happen inside the guard,
//! shard maps are replaced atomically via `Arc` swaps), so a thread
//! that panicked while holding a lock leaves the protected data in a
//! *consistent* state — the poison flag records that a panic happened,
//! not that the data is torn. Propagating the `PoisonError` (the old
//! `.lock().unwrap()` idiom) therefore converts one crashed worker
//! into a permanent denial of service: every later `lock()` panics
//! forever. These helpers recover the guard instead, which is the
//! behavior `std` itself recommends for consistent-by-construction
//! data (`PoisonError::into_inner`).
//!
//! Serving-path code uses these exclusively; `pallas-lint`'s
//! `serving-no-panic` rule flags the raw `.lock().unwrap()` form.

use std::sync::{Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// [`Mutex`] acquisition that recovers from poisoning.
pub trait MutexExt<T> {
    /// Lock, recovering the guard if a previous holder panicked.
    fn lock_recover(&self) -> MutexGuard<'_, T>;
}

impl<T> MutexExt<T> for Mutex<T> {
    fn lock_recover(&self) -> MutexGuard<'_, T> {
        self.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

/// [`RwLock`] acquisition that recovers from poisoning.
pub trait RwLockExt<T> {
    /// Shared-read, recovering the guard if a writer panicked.
    fn read_recover(&self) -> RwLockReadGuard<'_, T>;
    /// Exclusive-write, recovering the guard if a holder panicked.
    fn write_recover(&self) -> RwLockWriteGuard<'_, T>;
}

impl<T> RwLockExt<T> for RwLock<T> {
    fn read_recover(&self) -> RwLockReadGuard<'_, T> {
        self.read().unwrap_or_else(PoisonError::into_inner)
    }

    fn write_recover(&self) -> RwLockWriteGuard<'_, T> {
        self.write().unwrap_or_else(PoisonError::into_inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex, RwLock};

    #[test]
    fn mutex_recovers_after_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*m.lock_recover(), 7);
        *m.lock_recover() = 9;
        assert_eq!(*m.lock_recover(), 9);
    }

    #[test]
    fn rwlock_recovers_after_poison() {
        let l = Arc::new(RwLock::new(1u32));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(l.read().is_err(), "rwlock should be poisoned");
        assert_eq!(*l.read_recover(), 1);
        *l.write_recover() = 2;
        assert_eq!(*l.read_recover(), 2);
    }

    #[test]
    fn unpoisoned_path_is_transparent() {
        let m = Mutex::new(3u32);
        assert_eq!(*m.lock_recover(), 3);
        let l = RwLock::new(4u32);
        assert_eq!(*l.read_recover(), 4);
        assert_eq!(*l.write_recover(), 4);
    }
}
