//! Integration: the `lpsketch` binary's CLI surface, exercised through
//! the real executable (CARGO_BIN_EXE_lpsketch).

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_lpsketch"))
}

#[test]
fn ingest_synthetic_reports_storage() {
    let out = bin()
        .args(["--n", "64", "--d", "512", "--k", "64", "ingest"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("ingested 64 rows"), "{stdout}");
    assert!(stdout.contains("compression"), "{stdout}");
}

#[test]
fn query_prints_estimates() {
    let out = bin()
        .args(["--n", "32", "--d", "256", "--k", "64", "query", "0", "1", "2", "3"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("d(0,1):"), "{stdout}");
    assert!(stdout.contains("d(2,3):"), "{stdout}");
}

#[test]
fn pairs_writes_csv() {
    let dir = std::env::temp_dir().join("lpsketch_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("pairs.csv");
    let out = bin()
        .args([
            "--n", "10", "--d", "128", "--k", "32", "pairs", "--out",
            path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let csv = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = csv.lines().collect();
    assert_eq!(lines[0], "i,j,estimate");
    assert_eq!(lines.len(), 1 + 10 * 9 / 2);
    std::fs::remove_file(&path).ok();
}

#[test]
fn serve_answers_queries_during_concurrent_ingest() {
    let out = bin()
        .args([
            "--n", "48", "--d", "128", "--k", "32", "--query-workers", "2", "serve", "2",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("served 1000 pair queries"), "{stdout}");
    assert!(stdout.contains("while ingesting 48 rows concurrently"), "{stdout}");
    assert!(stdout.contains("in_flight=0"), "{stdout}");
}

#[test]
fn knn_on_corpus() {
    let out = bin()
        .args([
            "--n", "200", "--d", "256", "--k", "64", "knn", "3", "5", "--data", "corpus",
            "--rerank", "20",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("top-5 for row 3"), "{stdout}");
    // Self should be retrieved with exact distance 0 after reranking.
    assert!(stdout.contains("row      3"), "{stdout}");
}

#[test]
fn ingest_saves_loadable_sketches() {
    let dir = std::env::temp_dir().join("lpsketch_cli_persist");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("s.lpsk");
    let out = bin()
        .args([
            "--n", "24", "--d", "128", "--k", "16", "ingest", "--save-sketches",
            path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let (store, header) = lpsketch::coordinator::persist::load(&path, 2).unwrap();
    assert_eq!(header.rows, 24);
    assert_eq!(header.k, 16);
    assert_eq!(store.len(), 24);
    std::fs::remove_file(&path).ok();
}

#[test]
fn pairs_serves_from_saved_sketches() {
    // ingest --save-sketches then pairs --load-sketches: the saved
    // O(nk) state serves the export without the data matrix.
    let dir = std::env::temp_dir().join("lpsketch_cli_load");
    std::fs::create_dir_all(&dir).unwrap();
    let sketches = dir.join("s.lpsk");
    let csv_path = dir.join("pairs.csv");
    let out = bin()
        .args([
            "--n", "12", "--d", "128", "--k", "16", "ingest", "--save-sketches",
            sketches.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = bin()
        .args([
            "pairs", "--load-sketches", sketches.to_str().unwrap(), "--out",
            csv_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("restored 12 rows"), "{stdout}");
    let csv = std::fs::read_to_string(&csv_path).unwrap();
    assert_eq!(csv.lines().count(), 1 + 12 * 11 / 2);
    std::fs::remove_file(&sketches).ok();
    std::fs::remove_file(&csv_path).ok();
}

#[test]
fn unknown_flag_fails_with_usage() {
    let out = bin().args(["--bogus", "1", "ingest"]).output().unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("usage:"), "{stderr}");
}

#[test]
fn bad_p_rejected() {
    let out = bin().args(["--p", "5", "ingest"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn platform_lists_artifacts_when_built() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        return;
    }
    let out = bin().arg("platform").output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("platform:"), "{stdout}");
    assert!(stdout.contains("sketch_p4"), "{stdout}");
}
