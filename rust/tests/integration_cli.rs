//! Integration: the `lpsketch` binary's CLI surface, exercised through
//! the real executable (CARGO_BIN_EXE_lpsketch).

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_lpsketch"))
}

#[test]
fn ingest_synthetic_reports_storage() {
    let out = bin()
        .args(["--n", "64", "--d", "512", "--k", "64", "ingest"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("ingested 64 rows"), "{stdout}");
    assert!(stdout.contains("compression"), "{stdout}");
}

#[test]
fn query_prints_estimates() {
    let out = bin()
        .args(["--n", "32", "--d", "256", "--k", "64", "query", "0", "1", "2", "3"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("d(0,1):"), "{stdout}");
    assert!(stdout.contains("d(2,3):"), "{stdout}");
}

#[test]
fn pairs_writes_csv() {
    let dir = std::env::temp_dir().join("lpsketch_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("pairs.csv");
    let out = bin()
        .args([
            "--n", "10", "--d", "128", "--k", "32", "pairs", "--out",
            path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let csv = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = csv.lines().collect();
    assert_eq!(lines[0], "i,j,estimate");
    assert_eq!(lines.len(), 1 + 10 * 9 / 2);
    std::fs::remove_file(&path).ok();
}

#[test]
fn serve_answers_queries_during_concurrent_ingest() {
    let out = bin()
        .args([
            "--n", "48", "--d", "128", "--k", "32", "--query-workers", "2", "serve", "2",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("served 1000 pair queries"), "{stdout}");
    assert!(stdout.contains("while ingesting 48 rows concurrently"), "{stdout}");
    assert!(stdout.contains("in_flight=0"), "{stdout}");
}

#[test]
fn knn_on_corpus() {
    let out = bin()
        .args([
            "--n", "200", "--d", "256", "--k", "64", "knn", "3", "5", "--data", "corpus",
            "--rerank", "20",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("top-5 for row 3"), "{stdout}");
    // Self should be retrieved with exact distance 0 after reranking.
    assert!(stdout.contains("row      3"), "{stdout}");
}

#[test]
fn ingest_saves_loadable_sketches() {
    let dir = std::env::temp_dir().join("lpsketch_cli_persist");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("s.lpsk");
    let out = bin()
        .args([
            "--n", "24", "--d", "128", "--k", "16", "ingest", "--save-sketches",
            path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let (store, header) = lpsketch::coordinator::persist::load(&path, 2).unwrap();
    assert_eq!(header.rows, 24);
    assert_eq!(header.k, 16);
    assert_eq!(store.len(), 24);
    std::fs::remove_file(&path).ok();
}

#[test]
fn pairs_serves_from_saved_sketches() {
    // ingest --save-sketches then pairs --load-sketches: the saved
    // O(nk) state serves the export without the data matrix.
    let dir = std::env::temp_dir().join("lpsketch_cli_load");
    std::fs::create_dir_all(&dir).unwrap();
    let sketches = dir.join("s.lpsk");
    let csv_path = dir.join("pairs.csv");
    let out = bin()
        .args([
            "--n", "12", "--d", "128", "--k", "16", "ingest", "--save-sketches",
            sketches.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = bin()
        .args([
            "pairs", "--load-sketches", sketches.to_str().unwrap(), "--out",
            csv_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("restored 12 rows"), "{stdout}");
    let csv = std::fs::read_to_string(&csv_path).unwrap();
    assert_eq!(csv.lines().count(), 1 + 12 * 11 / 2);
    std::fs::remove_file(&sketches).ok();
    std::fs::remove_file(&csv_path).ok();
}

#[test]
fn unknown_flag_fails_with_usage() {
    let out = bin().args(["--bogus", "1", "ingest"]).output().unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("usage:"), "{stderr}");
}

#[test]
fn bad_p_rejected() {
    let out = bin().args(["--p", "5", "ingest"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn platform_lists_artifacts_when_built() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        return;
    }
    let out = bin().arg("platform").output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("platform:"), "{stdout}");
    assert!(stdout.contains("sketch_p4"), "{stdout}");
}

#[test]
fn rerank_bad_value_errors_loudly() {
    // `--rerank abc` used to parse as "no rerank" via .ok().unwrap_or(0);
    // bad values must error like every config key.
    let out = bin()
        .args(["--n", "32", "--d", "64", "--k", "16", "knn", "1", "3", "--rerank", "abc"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--rerank"), "{stderr}");
    // A missing value errors too.
    let out = bin().args(["knn", "1", "3", "--rerank"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn api_loopback_matches_direct_pipeline_calls_under_concurrent_ingest() {
    // The unified-API acceptance: every request kind answered over a
    // TCP loopback (and through the in-process service) must be
    // bitwise-identical to direct Pipeline calls — pair batches while a
    // writer ingests concurrently (estimates between pre-ingested rows
    // are write-invariant), the rest on the quiesced store.
    use std::sync::Arc;

    let mut cfg = lpsketch::config::Config::default();
    cfg.n = 48;
    cfg.d = 64;
    cfg.k = 32;
    cfg.block_rows = 16;
    cfg.workers = 2;
    let data = lpsketch::data::gen::generate(lpsketch::data::DataDist::Gaussian, 48, 64, 7);
    let pipeline = Arc::new(lpsketch::coordinator::Pipeline::new(cfg).unwrap());
    pipeline.ingest(&data).unwrap();

    let pairs: Vec<(u64, u64)> = (0..48u64).map(|i| (i, (i * 5 + 1) % 48)).collect();
    let pairs_direct = pipeline.estimate_pairs(&pairs);

    let service = pipeline.spawn_query_service();
    let guard = lpsketch::api::Server::bind("127.0.0.1:0", service.clone())
        .unwrap()
        .spawn()
        .unwrap();
    let addr = guard.addr();

    std::thread::scope(|s| {
        let writer = {
            let pipeline = Arc::clone(&pipeline);
            let data = &data;
            s.spawn(move || {
                for _ in 0..2 {
                    pipeline.ingest(data).unwrap();
                }
            })
        };
        // Remote client and in-process handle race the writer; answers
        // for pre-ingested ids must stay bitwise-stable throughout.
        let mut client = lpsketch::api::Client::connect(addr).unwrap();
        for _ in 0..20 {
            assert_eq!(client.pairs(&pairs).unwrap(), pairs_direct, "TCP loopback diverged");
            match service.call(lpsketch::api::Request::PairBatch(pairs.clone())).unwrap() {
                lpsketch::api::Response::PairBatch(got) => {
                    assert_eq!(got, pairs_direct, "in-process service diverged")
                }
                other => panic!("unexpected response {other:?}"),
            }
        }
        writer.join().unwrap();
    });
    assert_eq!(pipeline.rows(), 3 * 48);

    // Quiesced: the remaining request kinds, bitwise vs direct calls.
    let mut client = lpsketch::api::Client::connect(addr).unwrap();
    assert_eq!(client.pairs(&pairs).unwrap(), pipeline.estimate_pairs(&pairs));
    let by_id_direct = pipeline.top_k_ids(&[7], 6);
    assert_eq!(client.top_k_id(7, 6).unwrap(), by_id_direct[0].clone().unwrap());
    let q = data.row(11);
    assert_eq!(
        client.top_k_vector(q, 6).unwrap(),
        pipeline.top_k(&[q], 6).unwrap()[0]
    );
    let ids: Vec<u64> = (0..48).chain([9999]).collect();
    assert_eq!(
        client.vector_distances(q, &ids).unwrap(),
        pipeline.vector_distances(q, &ids).unwrap()
    );
    let stats = client.stats().unwrap();
    assert_eq!(stats.rows, 3 * 48);
    assert!(stats.projection_known);
    assert_eq!(client.ping().unwrap(), 1);
    // Unknown-id top-k is a typed error over the wire, not a hangup.
    let err = client.top_k_id(424242, 3).unwrap_err().to_string();
    assert!(err.contains("unknown id"), "{err}");
    // The connection survives the error response.
    assert_eq!(client.pairs(&pairs[..2]).unwrap(), pipeline.estimate_pairs(&pairs[..2]));
    // Metrics drained: no queries left in flight once all replies landed.
    assert_eq!(pipeline.metrics().queries_in_flight, 0);
    guard.stop();
}

#[test]
fn serve_listen_speaks_the_wire_protocol_to_the_client_subcommand() {
    // End-to-end over two processes: `serve --listen` prints its bound
    // address, the `client` subcommand drives it remotely, and a typed
    // api::Client gets answers bitwise-identical to a local pipeline
    // built from the same deterministic config + data.
    use std::io::BufRead;

    let mut child = bin()
        .args(["--n", "32", "--d", "64", "--k", "16", "serve", "--listen", "127.0.0.1:0"])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .unwrap();
    let stdout = child.stdout.take().unwrap();
    let mut reader = std::io::BufReader::new(stdout);
    let mut addr = String::new();
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line).unwrap() == 0 {
            let _ = child.kill();
            panic!("server exited before printing its address");
        }
        if let Some(rest) = line.trim().strip_prefix("listening on ") {
            addr = rest.to_string();
            break;
        }
    }

    // CLI client round-trips.
    let out = bin().args(["client", "--connect", &addr, "ping"]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("pong (protocol v1)"));
    let out = bin().args(["client", "--connect", &addr, "stats"]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("rows=32"), "{stdout}");
    let out = bin()
        .args(["client", "--connect", &addr, "query", "0", "1", "2", "3"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("d(0,1): estimate="), "{stdout}");
    assert!(stdout.contains("d(2,3): estimate="), "{stdout}");
    let out = bin()
        .args(["client", "--connect", &addr, "knn", "3", "4"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("top-4 for stored row 3"));

    // Typed client vs a local pipeline on the identical deterministic
    // workload: bitwise equality across the process boundary.
    let mut cfg = lpsketch::config::Config::default();
    cfg.n = 32;
    cfg.d = 64;
    cfg.k = 16;
    let data = lpsketch::data::gen::generate(cfg.data_dist, cfg.n, cfg.d, cfg.seed);
    let local = lpsketch::coordinator::Pipeline::new(cfg).unwrap();
    local.ingest(&data).unwrap();
    let mut client = lpsketch::api::Client::connect(addr.as_str()).unwrap();
    let pairs: Vec<(u64, u64)> = (0..32u64).map(|i| (i, (i + 9) % 32)).collect();
    assert_eq!(client.pairs(&pairs).unwrap(), local.estimate_pairs(&pairs));
    assert_eq!(
        client.top_k_id(5, 4).unwrap(),
        local.top_k_ids(&[5], 4)[0].clone().unwrap()
    );

    let _ = child.kill();
    let _ = child.wait();
}
