//! Fault-injection proof of the durability layer (`coordinator::durable`
//! / `wal` / `segfile` / `compactor`): every acknowledged ingest batch
//! survives a crash at every named fault site — torn record, short
//! write, fsync failure, rename failure, disk full — and recovery is
//! bitwise-equal to the unfailed store. Crashes are injected through
//! [`FaultFs`]; a "restart" recovers the same directory with a clean
//! [`RealFs`], exactly what a real process restart sees.

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use lpsketch::config::Config;
use lpsketch::coordinator::durable::DurableFs;
use lpsketch::coordinator::{compactor, persist, Durability, MetaShape, Pipeline, RealFs, SketchStore};
use lpsketch::data::{gen, DataDist};
use lpsketch::projection::sketcher::Sketcher;
use lpsketch::projection::{ProjectionDist, ProjectionSpec, Strategy};
use lpsketch::testkit;
use lpsketch::testkit::faultfs::{Fault, FaultAction, FaultOp, FaultFs};
use lpsketch::testkit::store::{random_store_pop, StorePop};

static DIR_SEQ: AtomicUsize = AtomicUsize::new(0);

/// Fresh scratch directory. Tag must not collide with fault path
/// substrings ("wal-", "seg", ".lpsk", ".tmp", "store.meta").
fn tmp_root(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("lpsketch_durability_it").join(format!(
        "{tag}_{}_{}",
        std::process::id(),
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The data-dir shape a population's rows conform to.
fn shape_for(pop: &StorePop) -> MetaShape {
    let mut cfg = Config::default();
    cfg.p = pop.p;
    cfg.k = pop.k;
    cfg.strategy = pop.strategy;
    cfg.seed = 7;
    cfg.dist = ProjectionDist::Normal;
    MetaShape::from_config(&cfg)
}

/// Drive a population through the durability layer the way ingest does
/// (insert-then-log: `Ok` from the log is the acknowledgement),
/// stopping at the first failed append — the "crash". Returns the
/// acknowledged ids: the map rows as one group-committed unit, then
/// each block as one batch record.
fn ingest_with_acks(dur: &Durability, store: &SketchStore, pop: &StorePop) -> Vec<u64> {
    let mut acked = Vec::new();
    if !pop.map_rows.is_empty() {
        for (id, rs) in &pop.map_rows {
            store.insert(*id, rs.clone());
        }
        if dur.log_rows(&pop.map_rows).is_err() {
            return acked;
        }
        acked.extend(pop.map_rows.iter().map(|(id, _)| *id));
    }
    for (base, block) in &pop.blocks {
        store.insert_block_columnar(*base, block.clone());
        if dur.log_block(*base, block).is_err() {
            return acked;
        }
        acked.extend(*base..*base + block.rows() as u64);
    }
    acked
}

/// Every id in `ids` must be present in `got` with a payload bitwise
/// equal to `want`'s.
fn assert_rows_bitwise(got: &SketchStore, want: &SketchStore, ids: &[u64], ctx: &str) {
    for &id in ids {
        let a = got.get(id).unwrap_or_else(|| panic!("{ctx}: acknowledged row {id} lost"));
        let b = want.get(id).expect("reference row");
        assert_eq!(a.uside.data, b.uside.data, "{ctx}: row {id} u-panel differs");
        assert_eq!(a.vside().data, b.vside().data, "{ctx}: row {id} v-panel differs");
        assert_eq!(a.moments.0, b.moments.0, "{ctx}: row {id} moments differ");
    }
}

/// Recovered rows must be exactly the population's rows, bitwise.
fn assert_store_bitwise(got: &SketchStore, pop: &StorePop, ctx: &str) {
    let reference = pop.build(2);
    assert_eq!(got.ids(), pop.ids(), "{ctx}: id set differs");
    assert_rows_bitwise(got, &reference, &pop.ids(), ctx);
}

fn reopen_clean(root: &std::path::Path, shape: MetaShape) -> lpsketch::coordinator::Opened {
    Durability::open(Arc::new(RealFs), root, shape, 2).expect("recovery must succeed")
}

// ---------------------------------------------------------------------------
// Crash during WAL append (ingest phase)
// ---------------------------------------------------------------------------

#[test]
fn acked_rows_survive_a_crash_at_every_wal_append_point() {
    // (name, fault): each models one crash while an append is in
    // flight. `skip` on the fsync fault steps over the open-time header
    // sync so the crash lands on a batch commit.
    let faults: Vec<(&str, Fault)> = vec![
        ("torn-nothing", Fault::new(FaultOp::AppendFile, "wal-", FaultAction::Torn { keep: 0 })),
        ("torn-short", Fault::new(FaultOp::AppendFile, "wal-", FaultAction::Torn { keep: 1 })),
        ("torn-header", Fault::new(FaultOp::AppendFile, "wal-", FaultAction::Torn { keep: 7 })),
        ("torn-mid", Fault::new(FaultOp::AppendFile, "wal-", FaultAction::Torn { keep: 41 })),
        // keep > record length: the bytes all land but the ack never
        // happens — recovery may legitimately resurface the batch.
        ("torn-landed", Fault::new(FaultOp::AppendFile, "wal-", FaultAction::Torn { keep: 1 << 20 })),
        ("die-before-append", Fault::new(FaultOp::AppendFile, "wal-", FaultAction::CrashBefore)),
        ("die-at-fsync", Fault::new(FaultOp::SyncFile, "wal-", FaultAction::CrashBefore).after(1)),
        // Second append crashes instead of the first.
        (
            "torn-later",
            Fault::new(FaultOp::AppendFile, "wal-", FaultAction::Torn { keep: 13 }).after(1),
        ),
        (
            "die-at-fsync-later",
            Fault::new(FaultOp::SyncFile, "wal-", FaultAction::CrashBefore).after(2),
        ),
    ];
    testkit::check(4, |g| {
        let pop = random_store_pop(g, 4);
        let shape = shape_for(&pop);
        let reference = pop.build(2);
        let all_ids: BTreeSet<u64> = pop.ids().into_iter().collect();
        for (name, fault) in &faults {
            let root = tmp_root("ap");
            let ffs = Arc::new(FaultFs::new(vec![fault.clone()]));
            let fs: Arc<dyn DurableFs> = ffs.clone();
            let opened = Durability::open(fs, &root, shape, 2).expect("fresh open");
            let acked = ingest_with_acks(&opened.durability, &opened.store, &pop);
            drop(opened);
            let re = reopen_clean(&root, shape);
            // Every acknowledged row survives, bitwise.
            assert_rows_bitwise(&re.store, &reference, &acked, name);
            // Recovery never invents rows: everything present came from
            // the population (an unacknowledged-but-landed batch may
            // legitimately resurface).
            for id in re.store.ids() {
                assert!(all_ids.contains(&id), "{name}: recovered unknown row {id}");
            }
            let _ = std::fs::remove_dir_all(&root);
        }
    });
}

#[test]
fn a_transient_append_error_rotates_and_keeps_logging() {
    testkit::check(3, |g| {
        let pop = random_store_pop(g, 3);
        let shape = shape_for(&pop);
        let root = tmp_root("rot");
        // One transient failure (EINTR-style): the op never happens,
        // later attempts succeed. The failed batch is NOT acknowledged;
        // every later batch must still be durable (poisoned-tail
        // rotation inside the layer).
        let ffs = Arc::new(FaultFs::new(vec![Fault::new(
            FaultOp::AppendFile,
            "wal-",
            FaultAction::Err,
        )]));
        let fs: Arc<dyn DurableFs> = ffs.clone();
        let opened = Durability::open(fs, &root, shape, 2).expect("fresh open");
        let reference = pop.build(2);
        let mut acked: Vec<u64> = Vec::new();
        let mut failed = 0usize;
        if !pop.map_rows.is_empty() {
            for (id, rs) in &pop.map_rows {
                opened.store.insert(*id, rs.clone());
            }
            match opened.durability.log_rows(&pop.map_rows) {
                Ok(_) => acked.extend(pop.map_rows.iter().map(|(id, _)| *id)),
                Err(_) => failed += 1,
            }
        }
        for (base, block) in &pop.blocks {
            opened.store.insert_block_columnar(*base, block.clone());
            match opened.durability.log_block(*base, block) {
                Ok(_) => acked.extend(*base..*base + block.rows() as u64),
                Err(_) => failed += 1,
            }
        }
        assert_eq!(failed, 1, "exactly the one injected failure");
        assert!(!ffs.crashed());
        drop(opened);
        let re = reopen_clean(&root, shape);
        assert_rows_bitwise(&re.store, &reference, &acked, "transient-append");
        let _ = std::fs::remove_dir_all(&root);
    });
}

// ---------------------------------------------------------------------------
// Crash during seal (segment publication / WAL rotation / cleanup)
// ---------------------------------------------------------------------------

#[test]
fn fully_acked_stores_survive_a_crash_at_every_seal_point() {
    let faults: Vec<(&str, Fault)> = vec![
        // Short segment write + crash (torn .tmp; never published).
        ("seg-torn", Fault::new(FaultOp::WriteFile, ".lpsk.tmp", FaultAction::Torn { keep: 10 })),
        ("seg-die-at-write", Fault::new(FaultOp::WriteFile, ".lpsk.tmp", FaultAction::CrashBefore)),
        ("seg-die-at-fsync", Fault::new(FaultOp::SyncFile, ".lpsk.tmp", FaultAction::CrashBefore)),
        // Rename failure: contents fsynced, publication never happens.
        ("seg-die-at-rename", Fault::new(FaultOp::Rename, ".lpsk", FaultAction::CrashBefore)),
        // Crash after the first segment published (partial seal).
        ("seg-die-second", Fault::new(FaultOp::SyncDir, "seg", FaultAction::CrashBefore)),
        // Rotated-WAL write crashes (segments on disk, rotation lost).
        (
            "rotate-die",
            Fault::new(FaultOp::WriteFile, "wal-", FaultAction::CrashBefore).after(1),
        ),
        ("rotate-torn", Fault::new(FaultOp::WriteFile, "wal-", FaultAction::Torn { keep: 11 }).after(1)),
        // Cleanup crashes: rotation done, stale files left behind.
        ("cleanup-die", Fault::new(FaultOp::RemoveFile, "wal-", FaultAction::CrashBefore)),
    ];
    testkit::check(4, |g| {
        let pop = random_store_pop(g, 3);
        let shape = shape_for(&pop);
        for (name, fault) in &faults {
            let root = tmp_root("sl");
            let ffs = Arc::new(FaultFs::new(vec![fault.clone()]));
            let fs: Arc<dyn DurableFs> = ffs.clone();
            let opened = Durability::open(fs, &root, shape, 2).expect("fresh open");
            let acked = ingest_with_acks(&opened.durability, &opened.store, &pop);
            assert_eq!(acked.len(), pop.total_rows(), "{name}: setup must fully ack");
            // The seal crashes somewhere; acknowledged data must not care.
            let _ = opened.durability.seal(&opened.store);
            drop(opened);
            let re = reopen_clean(&root, shape);
            assert_store_bitwise(&re.store, &pop, name);
            // A second restart (after the recovery's own seal) is
            // equally intact — recovery composes.
            drop(re);
            let again = reopen_clean(&root, shape);
            assert_store_bitwise(&again.store, &pop, &format!("{name}/second-restart"));
            let _ = std::fs::remove_dir_all(&root);
        }
    });
}

#[test]
fn a_clean_seal_then_restart_replays_nothing() {
    testkit::check(3, |g| {
        let pop = random_store_pop(g, 3);
        let shape = shape_for(&pop);
        let root = tmp_root("cs");
        let opened = Durability::open(Arc::new(RealFs), &root, shape, 2).expect("fresh open");
        let acked = ingest_with_acks(&opened.durability, &opened.store, &pop);
        assert_eq!(acked.len(), pop.total_rows());
        let sealed = opened.durability.seal(&opened.store).expect("seal");
        assert_eq!(sealed.segments_written as usize, opened.store.segment_count());
        assert_eq!(sealed.map_rows_logged as usize, pop.map_rows.len());
        drop(opened);
        let re = reopen_clean(&root, shape);
        assert_store_bitwise(&re.store, &pop, "clean-seal");
        // Unsealed replay applied only the map rows (from the rotated
        // WAL); all block rows came from sealed segment files.
        assert_eq!(re.report.segments_adopted as usize, pop.blocks.len());
        assert_eq!(re.report.wal_rows_applied as usize, pop.map_rows.len());
        assert_eq!(re.report.torn_tails, 0);
        let _ = std::fs::remove_dir_all(&root);
    });
}

// ---------------------------------------------------------------------------
// WAL byte-level corruption discipline
// ---------------------------------------------------------------------------

/// A small fixed population: 2 map rows + one 3-row block, k=4, p=4 —
/// small enough to recover once per byte offset.
fn tiny_pop(two_sided: bool) -> StorePop {
    let strategy = if two_sided { Strategy::Alternative } else { Strategy::Basic };
    let sk = Sketcher::new(ProjectionSpec::new(7, 4, ProjectionDist::Normal, strategy), 4);
    let row = |seed: usize| -> Vec<f32> {
        (0..10).map(|t| ((seed * 31 + t) as f32 * 0.37).sin()).collect()
    };
    let map_rows = vec![(3u64, sk.sketch_row(&row(1))), (9u64, sk.sketch_row(&row(2)))];
    let data: Vec<Vec<f32>> = (10..13).map(row).collect();
    let refs: Vec<&[f32]> = data.iter().map(|r| r.as_slice()).collect();
    let blocks = vec![(100u64, sk.sketch_block(&refs, 1))];
    StorePop { p: 4, k: 4, strategy, map_rows, blocks }
}

/// Write a pristine durable dir for `pop`, return (root, wal bytes,
/// record end offsets, ids per record in append order).
fn pristine_wal(pop: &StorePop, tag: &str) -> (PathBuf, Vec<u8>, Vec<usize>, Vec<Vec<u64>>) {
    let shape = shape_for(pop);
    let root = tmp_root(tag);
    let opened = Durability::open(Arc::new(RealFs), &root, shape, 2).expect("fresh open");
    let acked = ingest_with_acks(&opened.durability, &opened.store, pop);
    assert_eq!(acked.len(), pop.total_rows());
    drop(opened);
    let wal_dir = root.join("wal");
    let mut files: Vec<PathBuf> = std::fs::read_dir(&wal_dir)
        .unwrap()
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    files.sort();
    assert_eq!(files.len(), 1, "one WAL file after a fresh ingest");
    let full = std::fs::read(&files[0]).unwrap();
    // Parse record boundaries from the length prefixes.
    let mut ends = Vec::new();
    let mut off = 8usize;
    while off < full.len() {
        let len = u32::from_le_bytes(full[off..off + 4].try_into().unwrap()) as usize;
        off += 8 + len;
        ends.push(off);
    }
    assert_eq!(off, full.len(), "wal must end on a record boundary");
    // One record per map row, then one per block.
    let mut record_ids: Vec<Vec<u64>> = pop.map_rows.iter().map(|(id, _)| vec![*id]).collect();
    for (base, block) in &pop.blocks {
        record_ids.push((*base..*base + block.rows() as u64).collect());
    }
    assert_eq!(record_ids.len(), ends.len());
    (root, full, ends, record_ids)
}

/// Materialize a data dir whose only WAL file holds `wal_bytes`,
/// sharing `src_root`'s store.meta.
fn dir_with_wal(src_root: &std::path::Path, wal_bytes: &[u8], tag: &str) -> PathBuf {
    let root = tmp_root(tag);
    std::fs::copy(src_root.join("store.meta"), root.join("store.meta")).unwrap();
    std::fs::create_dir_all(root.join("wal")).unwrap();
    std::fs::create_dir_all(root.join("seg")).unwrap();
    std::fs::write(root.join("wal").join(format!("wal-{:016x}.wal", 0)), wal_bytes).unwrap();
    root
}

#[test]
fn every_byte_truncation_of_the_wal_tail_recovers_the_acked_prefix() {
    for two_sided in [false, true] {
        let pop = tiny_pop(two_sided);
        let shape = shape_for(&pop);
        let reference = pop.build(2);
        let (src, full, ends, record_ids) = pristine_wal(&pop, "tr");
        for cut in 0..=full.len() {
            let root = dir_with_wal(&src, &full[..cut], "trc");
            let re = reopen_clean(&root, shape);
            // Expected: exactly the records whose frame fits in `cut`
            // bytes (a tear can only lose the unfsynced tail).
            let mut want: Vec<u64> = record_ids
                .iter()
                .zip(&ends)
                .filter(|(_, &end)| end <= cut)
                .flat_map(|(ids, _)| ids.iter().copied())
                .collect();
            want.sort_unstable();
            assert_eq!(re.store.ids(), want, "cut at {cut} (two_sided={two_sided})");
            assert_rows_bitwise(&re.store, &reference, &want, &format!("cut {cut}"));
            // A cut at the header boundary or on a record boundary is a
            // clean (shorter) log; anything else must be counted torn.
            let clean = cut == 8 || ends.contains(&cut);
            assert_eq!(
                re.report.torn_tails > 0,
                !clean,
                "cut at {cut}: torn-tail accounting (two_sided={two_sided})"
            );
            let _ = std::fs::remove_dir_all(&root);
        }
        let _ = std::fs::remove_dir_all(&src);
    }
}

#[test]
fn bit_flips_tear_the_tail_but_hard_error_mid_log() {
    let pop = tiny_pop(false);
    let shape = shape_for(&pop);
    let reference = pop.build(2);
    let (src, full, ends, record_ids) = pristine_wal(&pop, "bf");
    assert!(ends.len() >= 3);
    // Flip inside the FIRST record's payload: settled data under CRC —
    // recovery must refuse the directory, not guess.
    let mut b = full.clone();
    b[8 + 8 + 2] ^= 0x40;
    let root = dir_with_wal(&src, &b, "bfa");
    assert!(
        Durability::open(Arc::new(RealFs), &root, shape, 2).is_err(),
        "mid-log corruption must be a hard error"
    );
    let _ = std::fs::remove_dir_all(&root);
    // Flip inside the LAST record: indistinguishable from a torn final
    // append — tolerated, last batch (never trustworthy) dropped.
    let mut b = full.clone();
    let last_start = ends[ends.len() - 2];
    b[last_start + 8 + 2] ^= 0x40;
    let root = dir_with_wal(&src, &b, "bfb");
    let re = reopen_clean(&root, shape);
    assert_eq!(re.report.torn_tails, 1);
    let mut want: Vec<u64> =
        record_ids[..record_ids.len() - 1].iter().flat_map(|ids| ids.iter().copied()).collect();
    want.sort_unstable();
    assert_eq!(re.store.ids(), want);
    assert_rows_bitwise(&re.store, &reference, &want, "last-record flip");
    let _ = std::fs::remove_dir_all(&root);
    // A flipped magic byte is not a WAL file at all.
    let mut b = full.clone();
    b[1] ^= 0xFF;
    let root = dir_with_wal(&src, &b, "bfc");
    assert!(Durability::open(Arc::new(RealFs), &root, shape, 2).is_err());
    let _ = std::fs::remove_dir_all(&root);
    // And the pristine bytes still recover everything (the harness
    // itself isn't what's failing).
    let root = dir_with_wal(&src, &full, "bfd");
    let re = reopen_clean(&root, shape);
    assert_store_bitwise(&re.store, &pop, "pristine");
    let _ = std::fs::remove_dir_all(&root);
    let _ = std::fs::remove_dir_all(&src);
}

// ---------------------------------------------------------------------------
// Replay idempotence and overlap rejection
// ---------------------------------------------------------------------------

#[test]
fn stale_duplicate_wal_replays_idempotently() {
    let pop = tiny_pop(true);
    let shape = shape_for(&pop);
    let (src, full, _, _) = pristine_wal(&pop, "dup");
    // A crashed cleanup can leave a stale WAL whose rows were already
    // sealed or re-logged: duplicate coverage must skip, not collide.
    std::fs::write(src.join("wal").join(format!("wal-{:016x}.wal", 1)), &full).unwrap();
    let re = reopen_clean(&src, shape);
    assert_store_bitwise(&re.store, &pop, "duplicate-wal");
    assert_eq!(re.report.wal_rows_skipped as usize, pop.total_rows());
    assert_eq!(re.report.wal_files, 2);
    let _ = std::fs::remove_dir_all(&src);
}

#[test]
fn partially_overlapping_batches_are_a_hard_error() {
    let pop = tiny_pop(false);
    let shape = shape_for(&pop);
    let root = tmp_root("ov");
    let block = pop.blocks[0].1.clone();
    {
        let opened = Durability::open(Arc::new(RealFs), &root, shape, 2).expect("fresh open");
        opened.store.insert_block_columnar(200, block.clone());
        opened.durability.log_block(200, &block).expect("ack");
        opened.durability.seal(&opened.store).expect("seal");
    }
    {
        // A corrupt writer logs a batch straddling the sealed range
        // [200, 203): recovery must refuse the directory rather than
        // keep either copy of the contested rows.
        let opened = reopen_clean(&root, shape);
        opened.durability.log_block(202, &block).expect("ack");
    }
    assert!(Durability::open(Arc::new(RealFs), &root, shape, 2).is_err());
    let _ = std::fs::remove_dir_all(&root);
}

// ---------------------------------------------------------------------------
// Snapshot (.lpsk v2/v3) + WAL coexistence
// ---------------------------------------------------------------------------

#[test]
fn v2_and_v3_snapshots_coexist_with_wal_replay_on_restore() {
    for version in [2u32, 3u32] {
        let pop = tiny_pop(version == 3);
        let shape = shape_for(&pop);
        let reference = pop.build(2);
        let store = pop.build(2);
        let staging = tmp_root("snstage");
        let staged = staging.join("staged.bin");
        // v3 carries the projection trailer; v2 is byte-identical up to
        // the version word minus that trailer — patch one from the other
        // (the legacy format the loader still accepts).
        persist::save(
            &store,
            pop.p,
            if version == 3 {
                Some(persist::ProjectionInfo { seed: 7, dist: ProjectionDist::Normal })
            } else {
                None
            },
            &staged,
        )
        .expect("save snapshot");
        let mut bytes = std::fs::read(&staged).unwrap();
        if version == 2 {
            bytes[4..8].copy_from_slice(&2u32.to_le_bytes());
            // Drop the one-byte "no projection" trailer flag at offset
            // 4+4+4*4+1+3*8 = 49; v2 headers end at the segment count.
            assert_eq!(bytes[49], 0);
            bytes.remove(49);
        }
        let root = tmp_root("sn");
        std::fs::write(root.join("snapshot.lpsk"), &bytes).unwrap();
        let opened = Durability::open(Arc::new(RealFs), &root, shape, 2).expect("open");
        assert_eq!(opened.report.snapshot_rows as usize, pop.total_rows(), "v{version}");
        assert_store_bitwise(&opened.store, &pop, &format!("v{version} snapshot"));
        // New ingest lands in the WAL alongside the snapshot.
        let block = pop.blocks[0].1.clone();
        opened.store.insert_block_columnar(500_000, block.clone());
        opened.durability.log_block(500_000, &block).expect("ack");
        drop(opened);
        let re = reopen_clean(&root, shape);
        assert_eq!(re.store.len(), pop.total_rows() + block.rows());
        assert_eq!(re.report.snapshot_rows as usize, pop.total_rows());
        assert_eq!(re.report.wal_rows_applied as usize, block.rows());
        assert_rows_bitwise(&re.store, &reference, &pop.ids(), &format!("v{version} restart"));
        let _ = std::fs::remove_dir_all(&root);
        let _ = std::fs::remove_dir_all(&staging);
    }
}

// ---------------------------------------------------------------------------
// Pipeline-level: degraded mode and end-to-end crash recovery
// ---------------------------------------------------------------------------

fn durable_pipeline(
    cfg: &Config,
    ffs: &Arc<FaultFs>,
    root: &std::path::Path,
) -> Arc<Pipeline> {
    let fs: Arc<dyn DurableFs> = ffs.clone();
    let shape = MetaShape::from_config(cfg);
    let opened = Durability::open(fs, root, shape, cfg.workers).expect("open");
    let mut pipeline =
        Pipeline::with_store_restored(cfg.clone(), opened.store, true).expect("pipeline");
    pipeline.attach_durability(Arc::new(opened.durability));
    Arc::new(pipeline)
}

fn small_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.n = 32;
    cfg.d = 24;
    cfg.k = 8;
    cfg.p = 4;
    cfg.block_rows = 8;
    cfg.workers = 1;
    cfg.compact_min_rows = 0;
    cfg
}

#[test]
fn degraded_mode_keeps_serving_and_heals_on_recovery() {
    let mut cfg = small_cfg();
    cfg.io_retry_max = 0;
    let root = tmp_root("dg");
    let ffs = Arc::new(FaultFs::new(vec![]));
    let pipeline = durable_pipeline(&cfg, &ffs, &root);
    let data = gen::generate(DataDist::Gaussian, cfg.n, cfg.d, 5);
    pipeline.ingest(&data).expect("durable ingest acks");
    // Data dir becomes unwritable for one segment publication.
    ffs.arm(Fault::new(FaultOp::WriteFile, ".lpsk.tmp", FaultAction::Err));
    compactor::run_pass(&pipeline);
    let dur = pipeline.durability().expect("attached");
    assert!(dur.degraded(), "exhausted retries must degrade");
    assert_eq!(pipeline.metrics().durable_degraded, 1);
    // Reads keep serving from memory while degraded — never a panic.
    let ests = pipeline.estimate_pairs(&[(0, 1), (2, 3), (30, 31)]);
    assert!(ests.iter().all(|e| e.is_some()), "queries must keep serving");
    // The directory heals (the fault was one-shot): the next pass
    // seals and clears the flag.
    compactor::run_pass(&pipeline);
    assert!(!pipeline.durability().expect("attached").degraded());
    assert_eq!(pipeline.metrics().durable_degraded, 0);
    assert!(pipeline.metrics().segments_sealed >= 1);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn transient_seal_errors_are_retried_with_backoff() {
    let mut cfg = small_cfg();
    cfg.io_retry_max = 4;
    let root = tmp_root("rt");
    let ffs = Arc::new(FaultFs::new(vec![]));
    let pipeline = durable_pipeline(&cfg, &ffs, &root);
    let data = gen::generate(DataDist::Gaussian, cfg.n, cfg.d, 6);
    pipeline.ingest(&data).expect("durable ingest acks");
    // Two consecutive transient failures, then the disk behaves.
    ffs.arm(Fault::new(FaultOp::WriteFile, ".lpsk.tmp", FaultAction::Err));
    ffs.arm(Fault::new(FaultOp::WriteFile, ".lpsk.tmp", FaultAction::Err));
    compactor::run_pass(&pipeline);
    assert!(!pipeline.durability().expect("attached").degraded(), "retries must absorb transients");
    assert_eq!(pipeline.metrics().io_retries, 2);
    assert!(pipeline.metrics().segments_sealed >= 1);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn disk_full_sticks_degraded_but_reads_survive() {
    let mut cfg = small_cfg();
    cfg.io_retry_max = 1;
    let root = tmp_root("df");
    let ffs = Arc::new(FaultFs::new(vec![]));
    let pipeline = durable_pipeline(&cfg, &ffs, &root);
    let data = gen::generate(DataDist::Uniform01, cfg.n, cfg.d, 7);
    pipeline.ingest(&data).expect("durable ingest acks");
    ffs.arm(Fault::new(FaultOp::WriteFile, ".lpsk.tmp", FaultAction::ErrSticky));
    for _ in 0..3 {
        compactor::run_pass(&pipeline);
        assert!(pipeline.durability().expect("attached").degraded());
        assert_eq!(pipeline.metrics().durable_degraded, 1);
        let ests = pipeline.estimate_pairs(&[(0, 1), (10, 20)]);
        assert!(ests.iter().all(|e| e.is_some()));
    }
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn pipeline_recovery_is_bitwise_equal_to_the_unfailed_pipeline() {
    let mut cfg = small_cfg();
    cfg.compact_min_rows = 1024;
    cfg.compact_target_rows = 4096;
    let root = tmp_root("e2e");
    let data1 = gen::generate(DataDist::Gaussian, 24, cfg.d, 11);
    let data2 = gen::generate(DataDist::Uniform01, 16, cfg.d, 12);
    let data3 = gen::generate(DataDist::Gaussian, 16, cfg.d, 13);
    // The durable run: ingest, seal (compact+seal pass, as the
    // background compactor would), ingest again, then crash on the
    // first WAL append of the third ingest.
    let ffs = Arc::new(FaultFs::new(vec![]));
    let pipeline = durable_pipeline(&cfg, &ffs, &root);
    pipeline.ingest(&data1).expect("ingest 1 acks");
    compactor::run_pass(&pipeline);
    pipeline.ingest(&data2).expect("ingest 2 acks");
    ffs.arm(Fault::new(FaultOp::AppendFile, "wal-", FaultAction::Torn { keep: 9 }));
    assert!(pipeline.ingest(&data3).is_err(), "crashed ingest must not ack");
    assert!(ffs.crashed());
    drop(pipeline);
    // The unfailed reference: same config, same first two ingests, no
    // durability in the way.
    let reference = Arc::new(Pipeline::new(cfg.clone()).expect("reference"));
    reference.ingest(&data1).expect("ref ingest 1");
    reference.ingest(&data2).expect("ref ingest 2");
    // Restart: recover the directory, serve, compare bitwise.
    let shape = MetaShape::from_config(&cfg);
    let re = reopen_clean(&root, shape);
    assert_eq!(re.store.len(), 40, "exactly the acknowledged rows");
    let recovered =
        Pipeline::with_store_restored(cfg.clone(), re.store, true).expect("recovered pipeline");
    let ids: Vec<u64> = (0..40).collect();
    let mut pairs = Vec::new();
    for (i, &a) in ids.iter().enumerate() {
        for &b in &ids[i + 1..] {
            pairs.push((a, b));
        }
    }
    let got = recovered.estimate_pairs(&pairs);
    let want = reference.estimate_pairs(&pairs);
    assert_eq!(got, want, "estimate_pairs must be bitwise-identical after recovery");
    let got_knn = recovered.top_k_ids(&ids, 5);
    let want_knn = reference.top_k_ids(&ids, 5);
    assert_eq!(got_knn, want_knn, "top_k must be bitwise-identical after recovery");
    let _ = std::fs::remove_dir_all(&root);
}
