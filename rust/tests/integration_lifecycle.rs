//! Lifecycle property suite: the columnar segment lifecycle —
//! persistence (v4 zone trailers included), compaction, segment-native
//! queries, and zone-pruned top-k — pinned
//! against the per-row reference path over random store populations
//! (map rows × segment blocks × ragged sizes, p ∈ {4, 6},
//! one/two-sided; see `testkit::store`).
//!
//! The invariant everywhere is *bitwise* equality: segments hold the
//! same f32 panels wherever they travel (disk, compaction, snapshots),
//! and every query kernel runs the same accumulation sequence, so
//! save → load → compact → query must reproduce the in-memory per-row
//! reference exactly — not approximately.

use std::sync::Arc;

use lpsketch::config::Config;
use lpsketch::coordinator::{persist, Pipeline, SketchStore, StoreSnapshot};
use lpsketch::core::decompose::Decomposition;
use lpsketch::core::estimator;
use lpsketch::data::{gen, DataDist};
use lpsketch::testkit::{self, store::StorePop};

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("lpsketch_lifecycle_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn cfg_for(pop: &StorePop, workers: usize) -> Config {
    let mut c = Config::default();
    c.p = pop.p;
    c.k = pop.k;
    c.strategy = pop.strategy;
    c.workers = workers;
    c
}

/// A pair batch large enough to engage the blocked/batched query path,
/// cycling through the population's ids, plus unknown-id probes.
fn pair_batch(ids: &[u64]) -> Vec<(u64, u64)> {
    let n = ids.len();
    let mut pairs: Vec<(u64, u64)> = (0..n.max(40))
        .map(|i| (ids[i % n], ids[(i * 7 + 3) % n]))
        .collect();
    pairs.push((ids[0], u64::MAX));
    pairs.push((u64::MAX, ids[n - 1]));
    pairs
}

#[test]
fn compaction_and_segment_native_queries_match_per_row_reference() {
    // The core lifecycle property: for random fully-columnar stores,
    // estimate_pairs, top-k KNN, and all_pairs_condensed are
    // bitwise-identical (1) before vs after compact_segments, (2) on the
    // segment-native path vs the all-map per-row mirror, and (3) across
    // worker counts.
    testkit::check(10, |g| {
        let pop = testkit::store::random_store_pop(g, 0);
        let ids = pop.ids();
        let pairs = pair_batch(&ids);
        let queries: Vec<Vec<f32>> = (0..3).map(|_| g.vec_f32(8..24, -2.0..2.0)).collect();
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        let mut runs = Vec::new();
        for workers in [1usize, 3] {
            let native = Pipeline::with_store(cfg_for(&pop, workers), pop.build(workers)).unwrap();
            let mirror =
                Pipeline::with_store(cfg_for(&pop, workers), pop.build_per_row(workers)).unwrap();
            assert!(native.metrics().segment_count > 0);
            assert_eq!(mirror.metrics().segment_count, 0);
            let before = (
                native.estimate_pairs(&pairs),
                native.all_pairs_condensed(),
                native.top_k(&qrefs, 7).unwrap(),
            );
            // Compact (merge everything adjacent), then re-query.
            native.store().compact_segments(1 << 20, 1 << 22);
            let after = (
                native.estimate_pairs(&pairs),
                native.all_pairs_condensed(),
                native.top_k(&qrefs, 7).unwrap(),
            );
            assert_eq!(before, after, "compaction changed an estimate");
            let mirrored = (
                mirror.estimate_pairs(&pairs),
                mirror.all_pairs_condensed(),
                mirror.top_k(&qrefs, 7).unwrap(),
            );
            assert_eq!(before, mirrored, "segment-native diverged from per-row mirror");
            // Snapshot-served view vs the pre-refactor lock-pinned
            // view: bitwise identical condensed scans.
            let dec = Decomposition::new(pop.p).unwrap();
            let via_snapshot = native
                .store()
                .with_columnar_view(pop.p, |v| {
                    v.map(|v| estimator::estimate_condensed_arena(&dec, v, workers))
                })
                .expect("fully columnar");
            let via_locked = native
                .store()
                .with_columnar_view_locked(pop.p, |v| {
                    v.map(|v| estimator::estimate_condensed_arena(&dec, v, workers))
                })
                .expect("fully columnar");
            assert_eq!(via_snapshot, via_locked, "snapshot view diverged from locked view");
            runs.push(before);
        }
        assert_eq!(runs[0], runs[1], "worker count changed an estimate");
    });
}

#[test]
fn persist_v2_round_trip_preserves_layout_and_estimates() {
    testkit::check(10, |g| {
        let pop = testkit::store::random_store_pop(g, 5);
        let store = pop.build(3);
        let path = tmp(&format!("roundtrip_{}.lpsk", g.case));
        let saved = persist::save(&store, pop.p, None, &path).unwrap();
        assert_eq!(saved.rows as usize, pop.total_rows());
        assert_eq!(saved.map_rows as usize, pop.map_rows.len());
        assert_eq!(saved.segments as usize, pop.blocks.len());
        let header = persist::read_header(&path).unwrap();
        assert_eq!(header, saved);
        let (loaded, _) = persist::load(&path, 2).unwrap();
        // Columnar layout preserved verbatim: same segment directory,
        // bitwise-equal blocks, same map rows, same byte accounting.
        assert_eq!(loaded.segments_snapshot(), store.segments_snapshot());
        assert_eq!(loaded.map_ids(), store.map_ids());
        assert_eq!(loaded.ids(), store.ids());
        assert_eq!(loaded.bytes(), store.bytes());
        // v4: zone summaries ride in the file and restore bitwise.
        for ((ab, _, az), (bb, _, bz)) in store
            .segments_snapshot_zoned()
            .iter()
            .zip(&loaded.segments_snapshot_zoned())
        {
            assert_eq!(ab, bb);
            assert_eq!(**az, **bz, "zone diverged through the roundtrip");
        }
        // And the same estimates, bitwise.
        let dec = lpsketch::core::decompose::Decomposition::new(pop.p).unwrap();
        let ids = pop.ids();
        for (i, &a) in ids.iter().enumerate().take(8) {
            let b = ids[(i * 5 + 1) % ids.len()];
            assert_eq!(
                loaded.estimate_pair_plain(&dec, a, b),
                store.estimate_pair_plain(&dec, a, b),
                "pair ({a},{b})"
            );
        }
        std::fs::remove_file(&path).ok();
    });
}

#[test]
fn corrupt_and_truncated_files_error_never_panic() {
    // Build one representative v2 file (map rows + segments), then
    // attack it: every truncation point and a set of header corruptions
    // must produce an error — never a panic, never an abort-scale
    // allocation.
    let mut g = testkit::Gen { rng: lpsketch::util::rng::Rng::new(7), case: 0 };
    let pop = testkit::store::random_store_pop(&mut g, 4);
    let store = pop.build(2);
    let path = tmp("attack.lpsk");
    let proj = persist::ProjectionInfo {
        seed: 11,
        dist: lpsketch::projection::ProjectionDist::Normal,
    };
    persist::save(&store, pop.p, Some(proj), &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();

    let attack = tmp("attacked.lpsk");
    // Truncations: every prefix length across the header plus strides
    // through the body.
    let mut cuts: Vec<usize> = (0..67.min(bytes.len())).collect();
    cuts.extend((67..bytes.len()).step_by(37));
    for cut in cuts {
        std::fs::write(&attack, &bytes[..cut]).unwrap();
        assert!(persist::load(&attack, 1).is_err(), "truncation at {cut} must error");
    }
    // Header corruptions: (offset, little-endian u32 value).
    for (off, val, what) in [
        (4usize, 99u32, "unsupported version"),
        (12, u32::MAX, "implausible k"),
        (16, u32::MAX, "implausible orders"),
        (20, u32::MAX, "implausible moment count"),
    ] {
        let mut b = bytes.clone();
        b[off..off + 4].copy_from_slice(&val.to_le_bytes());
        std::fs::write(&attack, &b).unwrap();
        assert!(persist::load(&attack, 1).is_err(), "{what} must error");
        assert!(persist::read_header(&attack).is_err() || off >= 25, "{what} header probe");
    }
    // Body corruptions via the u64 counters: map_rows (offset 33) and
    // segment count (offset 41) inflated far past the file size.
    for off in [25usize, 33, 41] {
        let mut b = bytes.clone();
        b[off..off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&attack, &b).unwrap();
        assert!(persist::load(&attack, 1).is_err(), "inflated counter at {off} must error");
    }
    // Internally inconsistent shape: moments must be 2·orders (a short
    // moment buffer would index out of bounds at query time).
    {
        let mut b = bytes.clone();
        b[20..24].copy_from_slice(&1u32.to_le_bytes());
        std::fs::write(&attack, &b).unwrap();
        assert!(persist::load(&attack, 1).is_err(), "short moment count must error");
    }
    // Duplicate map-row id: must be rejected, not silently collapsed.
    {
        let p2 = std::iter::repeat_with(|| testkit::store::random_store_pop(&mut g, 4))
            .take(100)
            .find(|p| p.map_rows.len() >= 2)
            .expect("a population with >= 2 map rows");
        let s2 = p2.build(2);
        persist::save(&s2, p2.p, Some(proj), &attack).unwrap();
        let mut b = std::fs::read(&attack).unwrap();
        let sides = if matches!(p2.strategy, lpsketch::projection::Strategy::Alternative) {
            2
        } else {
            1
        };
        let row_bytes = 8 + (p2.p - 1) * p2.k * 4 * sides + 2 * (p2.p - 1) * 8;
        // Overwrite the second row's id with the first's.
        let (id0_off, id1_off) = (67usize, 67 + row_bytes);
        let first_id = b[id0_off..id0_off + 8].to_vec();
        b[id1_off..id1_off + 8].copy_from_slice(&first_id);
        std::fs::write(&attack, &b).unwrap();
        assert!(persist::load(&attack, 1).is_err(), "duplicate map id must error");
    }
    std::fs::remove_file(&attack).ok();
}

/// Hand-rolled v1 writer (the pre-PR-3 row-wise format) so the
/// compatibility path is exercised against files we fully control.
fn write_v1(store: &lpsketch::coordinator::SketchStore, p: usize, path: &std::path::Path) {
    let ids = store.ids();
    let probe = store.get(ids[0]).unwrap();
    let (k, orders, nm) = (probe.uside.k, probe.uside.orders, probe.moments.len());
    let two_sided = probe.vside_data.is_some();
    let mut out: Vec<u8> = Vec::new();
    out.extend_from_slice(b"LPSK");
    for v in [1u32, p as u32, k as u32, orders as u32, nm as u32] {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out.push(two_sided as u8);
    out.extend_from_slice(&(ids.len() as u64).to_le_bytes());
    for id in ids {
        let rs = store.get(id).unwrap();
        out.extend_from_slice(&id.to_le_bytes());
        for x in &rs.uside.data {
            out.extend_from_slice(&x.to_le_bytes());
        }
        if let Some(v) = &rs.vside_data {
            for x in &v.data {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        for o in 1..=nm {
            out.extend_from_slice(&rs.moments.get(o).to_le_bytes());
        }
    }
    std::fs::write(path, out).unwrap();
}

#[test]
fn v1_files_still_load_into_the_map_path() {
    testkit::check(6, |g| {
        let pop = testkit::store::random_store_pop(g, 6);
        // v1 never held segments: write the per-row mirror.
        let mirror = pop.build_per_row(2);
        let path = tmp(&format!("v1_{}.lpsk", g.case));
        write_v1(&mirror, pop.p, &path);
        let header = persist::read_header(&path).unwrap();
        assert_eq!(header.segments, 0);
        assert_eq!(header.rows, header.map_rows);
        let (loaded, _) = persist::load(&path, 3).unwrap();
        assert_eq!(loaded.ids(), mirror.ids());
        assert!(loaded.segments_snapshot().is_empty());
        for &id in loaded.ids().iter().take(6) {
            let a = loaded.get(id).unwrap();
            let b = mirror.get(id).unwrap();
            assert_eq!(a.uside.data, b.uside.data);
            assert_eq!(a.vside().data, b.vside().data);
            assert_eq!(a.moments.0, b.moments.0);
        }
        std::fs::remove_file(&path).ok();
    });
}

#[test]
fn v1_golden_fixture_loads() {
    // An on-disk v1 file committed with the repo: guards the
    // compatibility path against both format drift and writer drift
    // (`write_v1` above shares no code with the fixture).
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/v1_golden.lpsk");
    let header = persist::read_header(&path).unwrap();
    assert_eq!(header.p, 4);
    assert_eq!(header.k, 4);
    assert_eq!(header.orders, 3);
    assert_eq!(header.moment_orders, 6);
    assert!(!header.two_sided);
    assert_eq!(header.rows, 3);
    assert_eq!(header.segments, 0);
    let (store, _) = persist::load(&path, 2).unwrap();
    assert_eq!(store.ids(), vec![0, 5, 9]);
    assert!(store.segments_snapshot().is_empty());
    // Payload values are the fixture generator's exact pattern:
    // u[m][j] = id + m + j/10, moments[o] = id + o/100 (f32 → f64 for
    // sketches, exact f64 for moments).
    for &id in &[0u64, 5, 9] {
        let rs = store.get(id).unwrap();
        assert_eq!(rs.uside.orders, 3);
        assert_eq!(rs.uside.k, 4);
        for m in 1..=3usize {
            for j in 0..4usize {
                let want = (id as f64 + m as f64 + j as f64 / 10.0) as f32;
                assert_eq!(rs.uside.u(m)[j], want, "id {id} m {m} j {j}");
            }
        }
        for o in 1..=6usize {
            let want = id as f64 + o as f64 / 100.0;
            assert_eq!(rs.moments.get(o), want, "id {id} moment {o}");
        }
    }
}

#[test]
fn save_load_compact_query_cycle_from_gemm_ingest() {
    // The acceptance cycle: GEMM ingest → save → load → adopt → compact
    // → every query path, bitwise-identical to the in-memory per-row
    // reference scoring on the original pipeline.
    let mut c = Config::default();
    c.n = 60;
    c.d = 96;
    c.k = 24;
    c.block_rows = 8;
    c.workers = 3;
    c.compact_min_rows = 0; // keep the raw per-block segments for this cycle
    let data = gen::generate(DataDist::Gaussian, c.n, c.d, 97);
    let origin = Pipeline::new(c.clone()).unwrap();
    origin.ingest(&data).unwrap();
    assert!(origin.metrics().segment_count > 1);
    // In-memory per-row reference: one estimate() per pair over
    // materialized RowSketches.
    let reference = origin.all_pairs_condensed_per_row();

    let path = tmp("cycle.lpsk");
    persist::save(
        origin.store(),
        c.p,
        Some(persist::ProjectionInfo { seed: c.seed, dist: c.dist }),
        &path,
    )
    .unwrap();
    let (loaded, header) = persist::load(&path, c.workers).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(header.segments as usize, origin.store().segment_count());
    // The regression pin: columnar layout must survive the round-trip
    // (the old format de-columnarized every row here).
    assert_eq!(
        loaded.segments_snapshot().is_empty(),
        origin.store().segments_snapshot().is_empty()
    );

    let mut cc = c.clone();
    cc.compact_min_rows = 64;
    let restored = Pipeline::with_store(cc, loaded).unwrap();
    assert!(restored.metrics().segment_count > 1);
    let compaction = restored.compact();
    assert!(compaction.merges >= 1);
    assert_eq!(restored.metrics().segment_count, 1);

    // Every query path reproduces the reference bitwise.
    assert_eq!(restored.all_pairs_condensed(), reference);
    let pairs = pair_batch(&restored.store().ids());
    let batched = restored.estimate_pairs(&pairs);
    for (&(a, b), got) in pairs.iter().zip(&batched) {
        assert_eq!(*got, origin.estimate_pair(a, b), "pair ({a},{b})");
    }
    let queries: Vec<&[f32]> = (0..3).map(|i| data.row(i * 17)).collect();
    assert_eq!(restored.top_k(&queries, 6).unwrap(), origin.top_k(&queries, 6).unwrap());
}

/// (ids, pair estimates, condensed all-pairs, top-k lists) of one scan.
type ScanResult = (Vec<u64>, Vec<Option<f64>>, Vec<f64>, Vec<Vec<(usize, f64)>>);

/// Every batch scan shape, computed from one snapshot: a pair batch,
/// the condensed all-pairs triangle, and a self-query top-k.
fn scan_all(snap: &StoreSnapshot, dec: &Decomposition, p: usize, k: usize) -> ScanResult {
    let ids = snap.ids();
    let pairs: Vec<(u64, u64)> =
        (0..60).map(|i| (ids[i % ids.len()], ids[(i * 7 + 3) % ids.len()])).collect();
    let pair_ests: Vec<Option<f64>> =
        pairs.iter().map(|&(a, b)| snap.estimate_pair_plain(dec, a, b)).collect();
    let (condensed, topk) = match snap.columnar_panels(p) {
        Some(v) => (
            estimator::estimate_condensed_arena(dec, &v, 2),
            estimator::top_k_scan_arena(dec, &v, &v, 5, 2),
        ),
        None => {
            let a = snap.arena(p, k);
            (
                estimator::estimate_condensed_arena(dec, &a.arena, 2),
                estimator::top_k_scan_arena(dec, &a.arena, &a.arena, 5, 2),
            )
        }
    };
    (ids, pair_ests, condensed, topk)
}

#[test]
fn concurrent_ingest_and_compaction_race_scans_consistently() {
    // The PR-4 stress property: while a writer ingests blocks and
    // compacts the store, concurrent scans run on epoch snapshots and
    // must (1) answer identically when replayed on the same snapshot
    // mid-race, and (2) be bitwise equal to a quiesced replay — the
    // same scans run on a fresh store rebuilt from nothing but the
    // snapshot's own state, after all writers finished.
    let mut c = Config::default();
    c.n = 64;
    c.d = 64;
    c.k = 16;
    c.block_rows = 8;
    c.workers = 2;
    c.compact_min_rows = 0; // the writer drives compaction explicitly
    let (p, k) = (c.p, c.k);
    let data = gen::generate(DataDist::Gaussian, c.n, c.d, 13);
    let pipeline = Pipeline::new(c.clone()).unwrap();
    pipeline.ingest(&data).unwrap();
    let store = pipeline.store();
    let dec = Decomposition::new(p).unwrap();

    std::thread::scope(|s| {
        let writer = s.spawn(|| {
            for _ in 0..3 {
                pipeline.ingest(&data).unwrap();
                store.compact_segments(1 << 20, 1 << 22);
            }
        });
        for _ in 0..2 {
            s.spawn(|| {
                for _ in 0..4 {
                    let snap = store.snapshot();
                    let r1 = scan_all(&snap, &dec, p, k);
                    // Replay on the same snapshot while the writer is
                    // still mutating the store underneath.
                    let r2 = scan_all(&snap, &dec, p, k);
                    assert_eq!(r1, r2, "snapshot changed answers across replays");
                    // Quiesced replay: a fresh store holding exactly the
                    // snapshot's state must scan bitwise-identically.
                    let rebuilt = SketchStore::new(3);
                    for seg in snap.segments() {
                        rebuilt.insert_block_shared(seg.base, Arc::clone(&seg.block));
                    }
                    for id in snap.map_ids() {
                        rebuilt.insert(id, snap.get(id).unwrap());
                    }
                    let r3 = scan_all(&rebuilt.snapshot(), &dec, p, k);
                    assert_eq!(r1, r3, "concurrent scan diverged from quiesced replay");
                }
            });
        }
        writer.join().unwrap();
    });
    assert_eq!(pipeline.rows(), 4 * 64);
}

#[test]
fn writers_are_never_blocked_behind_a_scan() {
    // Deterministic non-blocking handshake: a reader parks *inside* a
    // columnar view while a writer inserts a block and compacts. With
    // the old lock-pinned views this deadlocks (the writer waits on the
    // reader's read locks, the reader waits on the writer's message);
    // with snapshot views the writer only ever waits one snapshot
    // capture, so the handshake completes.
    let mut g = testkit::Gen { rng: lpsketch::util::rng::Rng::new(21), case: 0 };
    let pop = testkit::store::random_store_pop(&mut g, 0);
    let store = pop.build(2);
    let n_before = store.len();
    // A shape-compatible writer payload: one of the store's own blocks,
    // re-landed by Arc handle at a far-away base.
    let spare = store.segments_snapshot()[0].1.clone();
    let p = pop.p;
    let (tx_in, rx_in) = std::sync::mpsc::channel::<()>();
    let (tx_done, rx_done) = std::sync::mpsc::channel::<()>();
    let store_ref = &store;
    std::thread::scope(|s| {
        s.spawn(move || {
            store_ref.with_columnar_view(p, |v| {
                let v = v.expect("fully columnar population");
                tx_in.send(()).unwrap();
                // Sit mid-scan until the writer has inserted+compacted.
                rx_done.recv().unwrap();
                // Staleness semantics: the view keeps serving the epoch
                // it captured — the concurrent insert is invisible.
                assert_eq!(v.n(), n_before);
            });
        });
        rx_in.recv().unwrap();
        store.insert_block_shared(1_000_000, Arc::clone(&spare));
        store.compact_segments(1 << 20, 1 << 22);
        tx_done.send(()).unwrap();
    });
    assert_eq!(store.len(), n_before + spare.rows());
}

#[test]
fn pruned_top_k_is_bitwise_identical_to_full_scan() {
    // The pruning-equivalence property: over random fully-columnar
    // populations (p ∈ {4, 6}, one/two-sided, ragged segment sizes —
    // including 1-row segments the generator draws), the zoned
    // self-query top-k is bitwise-identical to the unpruned full scan
    // for every k — including k ≥ n — and every worker count. The
    // bound is admissible w.r.t. the *estimated* distances (same dot /
    // coefficient algebra, deflated by the fp margin), so pruning may
    // only skip segments that provably cannot contribute.
    testkit::check(12, |g| {
        let pop = testkit::store::random_store_pop(g, 0);
        let store = pop.build(2);
        let snap = store.snapshot();
        let v = snap.columnar_panels(pop.p).expect("fully columnar population");
        let dec = Decomposition::new(pop.p).unwrap();
        let extents = v.extents();
        let n = pop.total_rows();
        for top in [1usize, 5, n, n + 3] {
            for workers in [1usize, 3] {
                let full = estimator::top_k_scan_arena(&dec, &v, &v, top, workers);
                let (pruned, stats) =
                    estimator::top_k_scan_zoned(&dec, &v, &v, &extents, top, workers);
                assert_eq!(pruned, full, "pruned top-{top} diverged (workers={workers})");
                // Every (query, extent) pair is accounted for exactly
                // once — either scanned or skipped.
                assert_eq!(
                    stats.segments_visited + stats.segments_skipped,
                    (n as u64) * extents.len() as u64
                );
            }
        }
    });
}

#[test]
fn pruned_top_k_handles_adversarial_zone_shapes() {
    // Degenerate zones the bound must survive: (1) every row identical
    // — zero-width zones, ties on every distance, where the heap's
    // lower-index preference must not be disturbed by visit order; and
    // (2) a store of single-row segments — maximal extent count,
    // minimal rows per bound evaluation.
    let mut g = testkit::Gen { rng: lpsketch::util::rng::Rng::new(33), case: 0 };
    for strategy in
        [lpsketch::projection::Strategy::Basic, lpsketch::projection::Strategy::Alternative]
    {
        let p = 4;
        let sk = lpsketch::projection::sketcher::Sketcher::new(
            lpsketch::projection::ProjectionSpec::new(
                9,
                8,
                lpsketch::projection::ProjectionDist::Normal,
                strategy,
            ),
            p,
        );
        let dec = Decomposition::new(p).unwrap();
        // (1) identical rows split across three segments.
        let row = g.vec_f32(16..17, -2.0..2.0);
        let refs: Vec<&[f32]> = std::iter::repeat(row.as_slice()).take(12).collect();
        let store = SketchStore::new(2);
        store.insert_block_columnar(100, sk.sketch_block(&refs[..4], 1));
        store.insert_block_columnar(104, sk.sketch_block(&refs[4..6], 1));
        store.insert_block_columnar(106, sk.sketch_block(&refs[6..], 1));
        let snap = store.snapshot();
        let v = snap.columnar_panels(p).unwrap();
        for top in [1usize, 3, 12, 20] {
            let full = estimator::top_k_scan_arena(&dec, &v, &v, top, 2);
            let (pruned, _) = estimator::top_k_scan_zoned(&dec, &v, &v, &v.extents(), top, 2);
            assert_eq!(pruned, full, "tie ordering diverged at top-{top}");
            // All-identical rows: distances tie everywhere, so the heap
            // must keep the lowest indices, in ascending order.
            let want: Vec<usize> = (0..top.min(12)).collect();
            for list in &pruned {
                let got: Vec<usize> = list.iter().map(|&(i, _)| i).collect();
                assert_eq!(got, want, "ties must resolve to ascending indices");
            }
        }
        // (2) single-row segments.
        let rows: Vec<Vec<f32>> = (0..7).map(|_| g.vec_f32(16..17, -2.0..2.0)).collect();
        let store = SketchStore::new(2);
        for (i, r) in rows.iter().enumerate() {
            store.insert_block_columnar(
                200 + 10 * i as u64,
                sk.sketch_block(&[r.as_slice()], 1),
            );
        }
        let snap = store.snapshot();
        let v = snap.columnar_panels(p).unwrap();
        assert_eq!(v.extents().len(), 7);
        for top in [1usize, 4, 7, 9] {
            let full = estimator::top_k_scan_arena(&dec, &v, &v, top, 1);
            let (pruned, _) = estimator::top_k_scan_zoned(&dec, &v, &v, &v.extents(), top, 1);
            assert_eq!(pruned, full, "single-row segments diverged at top-{top}");
        }
    }
}

#[test]
fn pruned_top_k_skips_segments_on_skewed_stores() {
    // Pruning must actually fire, not just be harmless: on populations
    // whose segments sit at 1×/4×/16×/64× magnitude bands, the p-norm
    // lower bound of a far band exceeds any near-band heap threshold,
    // so the zoned scan provably skips whole segments — while staying
    // bitwise-identical to the full scan.
    testkit::check(8, |g| {
        let pop = testkit::store::skewed_store_pop(g);
        let store = pop.build(2);
        let snap = store.snapshot();
        let v = snap.columnar_panels(pop.p).expect("fully columnar population");
        let dec = Decomposition::new(pop.p).unwrap();
        let full = estimator::top_k_scan_arena(&dec, &v, &v, 2, 2);
        let (pruned, stats) = estimator::top_k_scan_zoned(&dec, &v, &v, &v.extents(), 2, 2);
        assert_eq!(pruned, full, "pruned scan diverged on skewed store");
        assert!(
            stats.segments_skipped > 0,
            "skewed bands must prune (visited={}, skipped={})",
            stats.segments_visited,
            stats.segments_skipped
        );
        assert!(stats.rows_skipped > 0);
    });
}

#[test]
fn incremental_serving_index_race_matches_cold_rebuild() {
    // The serving-index stress property: readers refresh their KNN
    // index incrementally (reusing shards whose segment blocks are
    // pointer-identical) while a writer ingests and compacts. Every
    // refreshed index must answer bitwise-identically to a cold rebuild
    // from the same snapshot, and refresh work is bounded by what
    // actually changed.
    use lpsketch::knn::KnnIndex;
    let mut c = Config::default();
    c.n = 48;
    c.d = 48;
    c.k = 16;
    c.block_rows = 8;
    c.workers = 2;
    c.compact_min_rows = 0; // the writer drives compaction explicitly
    let data = gen::generate(DataDist::Gaussian, c.n, c.d, 29);
    let pipeline = Pipeline::new(c.clone()).unwrap();
    pipeline.ingest(&data).unwrap();
    let store = pipeline.store();
    let spec = c.projection_spec();
    let p = c.p;

    std::thread::scope(|s| {
        let writer = s.spawn(|| {
            for _ in 0..3 {
                pipeline.ingest(&data).unwrap();
                store.compact_segments(1 << 20, 1 << 22);
            }
        });
        for _ in 0..2 {
            let spec = spec.clone();
            s.spawn(move || {
                let mut prev: Option<(u64, KnnIndex)> = None;
                for _ in 0..4 {
                    let snap = store.snapshot();
                    let (idx, ids, reindexed) = KnnIndex::from_snapshot_incremental(
                        &snap,
                        spec.clone(),
                        p,
                        prev.as_ref().map(|(_, i)| i),
                    )
                    .unwrap();
                    let (cold, cold_ids) =
                        KnnIndex::from_snapshot(&snap, spec.clone(), p).unwrap();
                    assert_eq!(ids, cold_ids);
                    for pos in [0usize, 7, ids.len() - 1] {
                        assert_eq!(
                            idx.query_pos(pos, 5),
                            cold.query_pos(pos, 5),
                            "incremental index diverged from cold rebuild at pos {pos}"
                        );
                    }
                    // A quiescent snapshot re-indexes nothing; a changed
                    // one at most its current segment count.
                    if let Some((prev_epoch, _)) = &prev {
                        if snap.epoch() == *prev_epoch {
                            assert_eq!(reindexed, 0, "unchanged snapshot re-indexed segments");
                        }
                    }
                    assert!(reindexed <= snap.segment_count());
                    prev = Some((snap.epoch(), idx));
                }
            });
        }
        writer.join().unwrap();
    });
    assert_eq!(pipeline.rows(), 4 * 48);
}

#[test]
fn restored_store_answers_fresh_vector_queries_like_the_origin() {
    // Satellite pin for the recorded projection: a store restored from
    // a v3 sketch file (seed + distribution in the header) must sketch
    // never-ingested query vectors bit-identically to the original
    // pipeline — top-k by fresh vector and vector distances included.
    // A file without the recorded projection must refuse those queries
    // instead of answering them wrong.
    let mut c = Config::default();
    c.n = 48;
    c.d = 80;
    c.k = 16;
    c.block_rows = 16;
    c.workers = 2;
    c.seed = 1234;
    c.dist = lpsketch::projection::ProjectionDist::ThreePoint(3.0);
    let data = gen::generate(DataDist::Gaussian, c.n, c.d, 55);
    let origin = Pipeline::new(c.clone()).unwrap();
    origin.ingest(&data).unwrap();
    let path = tmp("fresh_vectors.lpsk");
    persist::save(
        origin.store(),
        c.p,
        Some(persist::ProjectionInfo { seed: c.seed, dist: c.dist }),
        &path,
    )
    .unwrap();
    let header = persist::read_header(&path).unwrap();
    let info = header.projection.expect("v3 files record the projection");
    assert_eq!(info.seed, c.seed);
    assert_eq!(info.dist, c.dist);
    // Restore the way the CLI does: shape + projection from the header.
    let mut rc = Config::default();
    rc.p = header.p as usize;
    rc.k = header.k as usize;
    rc.d = rc.d.max(rc.k);
    rc.workers = 2;
    rc.seed = info.seed;
    rc.dist = info.dist;
    let (store, _) = persist::load(&path, rc.workers).unwrap();
    rc.n = store.len();
    let restored = Pipeline::with_store_restored(rc, store, true).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(restored.projection_known());
    // Fresh (never-ingested) query vectors: the stable-projection
    // workload. Bitwise equality with the origin pipeline.
    let queries: Vec<Vec<f32>> = (0..3)
        .map(|q| (0..80).map(|t| ((q * 31 + t) as f32 * 0.13).sin()).collect())
        .collect();
    let qrefs: Vec<&[f32]> = queries.iter().map(|v| v.as_slice()).collect();
    assert_eq!(restored.top_k(&qrefs, 6).unwrap(), origin.top_k(&qrefs, 6).unwrap());
    let ids: Vec<u64> = (0..48).collect();
    assert_eq!(
        restored.vector_distances(&queries[0], &ids).unwrap(),
        origin.vector_distances(&queries[0], &ids).unwrap()
    );
    // The same store restored as projection-unknown refuses, loudly.
    let (store2, _) = {
        let path2 = tmp("fresh_vectors2.lpsk");
        persist::save(origin.store(), c.p, None, &path2).unwrap();
        assert_eq!(persist::read_header(&path2).unwrap().projection, None);
        let out = persist::load(&path2, 2).unwrap();
        std::fs::remove_file(&path2).ok();
        out
    };
    let mut rc2 = c.clone();
    rc2.n = store2.len();
    let blind = Pipeline::with_store_restored(rc2, store2, false).unwrap();
    let err = blind.top_k(&qrefs, 6).unwrap_err().to_string();
    assert!(err.contains("projection parameters"), "{err}");
    assert!(blind.vector_distances(&queries[0], &ids).is_err());
    // Stored-id queries are unaffected by the missing projection.
    assert_eq!(blind.top_k_ids(&[5], 6), origin.top_k_ids(&[5], 6));
    assert_eq!(blind.estimate_pairs(&[(1, 2)]), origin.estimate_pairs(&[(1, 2)]));
}
