//! Integration: the full coordinator stack (scheduler → workers → store
//! → query paths) against the exact baseline, on the pure-rust backend.

use std::sync::Arc;

use lpsketch::baselines::exact;
use lpsketch::config::Config;
use lpsketch::coordinator::Pipeline;
use lpsketch::data::{corpus, gen, DataDist};

fn cfg(n: usize, d: usize, k: usize) -> Config {
    let mut c = Config::default();
    c.n = n;
    c.d = d;
    c.k = k;
    c.workers = 4;
    c.block_rows = 32;
    c.queue_depth = 4;
    c
}

/// Pearson correlation between two equal-length vectors.
fn correlation(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let (ma, mb) = (
        a.iter().sum::<f64>() / n,
        b.iter().sum::<f64>() / n,
    );
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (&x, &y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    cov / (va.sqrt() * vb.sqrt())
}

#[test]
fn all_pairs_estimates_correlate_with_exact() {
    let c = cfg(96, 512, 96);
    let data = gen::generate(DataDist::ZipfTf { exponent: 1.1, density: 0.1 }, c.n, c.d, 11);
    let pipeline = Pipeline::new(c.clone()).unwrap();
    pipeline.ingest(&data).unwrap();
    let est = pipeline.all_pairs_condensed();
    let exact = exact::pairwise_condensed(&data, c.p, 4);
    assert_eq!(est.len(), exact.len());
    let r = correlation(&est, &exact);
    assert!(r > 0.9, "correlation {r}");
}

#[test]
fn mle_improves_aggregate_error_on_corpus() {
    // On similar non-negative rows the margin MLE (Lemma 4) should cut
    // the aggregate relative error vs the plain estimator.
    let base = cfg(64, 512, 64);
    let data = corpus::generate(base.n, base.d, 80, 13).tf;
    let exact = exact::pairwise_condensed(&data, base.p, 4);

    let mean_rel = |use_mle: bool| {
        let mut c = base.clone();
        c.use_mle = use_mle;
        let p = Pipeline::new(c).unwrap();
        p.ingest(&data).unwrap();
        let est = p.all_pairs_condensed();
        let mut rel = 0.0;
        let mut count = 0usize;
        for (&e, &g) in exact.iter().zip(&est) {
            if e > 0.0 {
                rel += (g - e).abs() / e;
                count += 1;
            }
        }
        rel / count as f64
    };
    let plain = mean_rel(false);
    let mle = mean_rel(true);
    assert!(
        mle < plain,
        "MLE should reduce aggregate rel err: plain={plain:.4} mle={mle:.4}"
    );
}

#[test]
fn query_service_under_concurrent_load() {
    let c = cfg(128, 256, 32);
    let data = gen::generate(DataDist::Uniform01, c.n, c.d, 17);
    let pipeline = Arc::new(Pipeline::new(c).unwrap());
    pipeline.ingest(&data).unwrap();
    let service = pipeline.spawn_query_service();
    let mut threads = Vec::new();
    for t in 0..8u64 {
        let service = service.clone();
        threads.push(std::thread::spawn(move || {
            for i in 0..200u64 {
                let a = (t * 37 + i) % 128;
                let b = (t * 91 + i * 3 + 1) % 128;
                let got = service.query(a, b).unwrap();
                assert!(got.is_some());
                if a != b {
                    assert!(got.unwrap().is_finite());
                }
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    let snap = pipeline.metrics();
    assert_eq!(snap.queries_served, 8 * 200);
    assert!(snap.batches_flushed > 0);
}

#[test]
fn ingest_is_deterministic_across_worker_counts() {
    // Same seed ⇒ identical sketches regardless of parallelism (the
    // projection is counter-based, not stateful).
    let data = gen::generate(DataDist::Gaussian, 50, 128, 23);
    let run = |workers: usize| {
        let mut c = cfg(50, 128, 32);
        c.workers = workers;
        let p = Pipeline::new(c).unwrap();
        p.ingest(&data).unwrap();
        p.all_pairs_condensed()
    };
    let a = run(1);
    let b = run(7);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-12, "{x} vs {y}");
    }
}

#[test]
fn p6_pipeline_end_to_end() {
    // Gaussian rows with per-row scales: pairwise distances then span
    // orders of magnitude (scale⁶), so correlation against exact is
    // meaningful despite the p=6 estimator's heavy noise. (On uniform
    // non-negative rows all pairs are nearly equidistant and correlation
    // measures pure noise.)
    let mut c = cfg(48, 256, 128);
    c.p = 6;
    let mut data = gen::generate(DataDist::Gaussian, c.n, c.d, 29);
    for i in 0..data.n() {
        let s = 0.5 + 1.5 * i as f32 / 48.0;
        for v in data.row_mut(i) {
            *v *= s;
        }
    }
    let pipeline = Pipeline::new(c.clone()).unwrap();
    pipeline.ingest(&data).unwrap();
    let est = pipeline.all_pairs_condensed();
    let exact = exact::pairwise_condensed(&data, 6, 4);
    let r = correlation(&est, &exact);
    assert!(r > 0.7, "p=6 correlation {r}");
}

#[test]
fn alternative_strategy_pipeline_end_to_end() {
    let mut c = cfg(48, 512, 128);
    c.strategy = lpsketch::projection::Strategy::Alternative;
    let data = gen::generate(DataDist::ZipfTf { exponent: 1.1, density: 0.1 }, c.n, c.d, 31);
    let pipeline = Pipeline::new(c).unwrap();
    pipeline.ingest(&data).unwrap();
    let est = pipeline.all_pairs_condensed();
    let exact = exact::pairwise_condensed(&data, 4, 4);
    let r = correlation(&est, &exact);
    assert!(r > 0.8, "alt-strategy correlation {r}");
}
