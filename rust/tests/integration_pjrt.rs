//! Integration: the PJRT path — AOT artifacts executed from the
//! pipeline, cross-checked against the pure-rust backend (the 3-way
//! invariant of DESIGN.md §7; the python side is checked by pytest).
//!
//! These tests no-op silently if `artifacts/` has not been built.

use std::path::Path;

use lpsketch::config::Config;
use lpsketch::coordinator::Pipeline;
use lpsketch::data::{gen, DataDist};
use lpsketch::projection::Strategy;
use lpsketch::runtime::{fallback, Engine, OpKind, OwnedInput};

fn have_artifacts() -> bool {
    Path::new("artifacts/manifest.txt").exists()
}

fn cfg_pjrt(n: usize, strategy: Strategy) -> Config {
    let mut c = Config::default();
    c.n = n;
    c.d = 1024; // artifact grid width
    c.k = 64; // artifact grid k
    c.block_rows = 64; // artifact batch
    c.workers = 2;
    c.use_pjrt = true;
    c.strategy = strategy;
    c
}

#[test]
fn pjrt_pipeline_matches_rust_pipeline() {
    if !have_artifacts() {
        return;
    }
    let data = gen::generate(DataDist::Uniform01, 96, 1024, 41);
    let mut c_rust = cfg_pjrt(96, Strategy::Basic);
    c_rust.use_pjrt = false;
    let rust = Pipeline::new(c_rust).unwrap();
    rust.ingest(&data).unwrap();
    let pjrt = Pipeline::new(cfg_pjrt(96, Strategy::Basic)).unwrap();
    let report = pjrt.ingest(&data).unwrap();
    assert_eq!(report.pjrt_rows, 96, "all rows should take the PJRT path");
    assert!(pjrt.metrics().pjrt_calls > 0);

    let a = rust.all_pairs_condensed();
    let b = pjrt.all_pairs_condensed();
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        // f32 artifact vs f64-moment rust path: tolerances are relative
        // to the pair magnitude.
        let tol = 1e-2 * (1.0 + x.abs());
        assert!((x - y).abs() < tol, "pair {i}: rust={x} pjrt={y}");
    }
}

#[test]
fn pjrt_pipeline_alternative_strategy() {
    if !have_artifacts() {
        return;
    }
    let data = gen::generate(DataDist::Uniform01, 64, 1024, 43);
    let mut c_rust = cfg_pjrt(64, Strategy::Alternative);
    c_rust.use_pjrt = false;
    let rust = Pipeline::new(c_rust).unwrap();
    rust.ingest(&data).unwrap();
    let pjrt = Pipeline::new(cfg_pjrt(64, Strategy::Alternative)).unwrap();
    pjrt.ingest(&data).unwrap();
    let a = rust.all_pairs_condensed();
    let b = pjrt.all_pairs_condensed();
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        let tol = 1e-2 * (1.0 + x.abs());
        assert!((x - y).abs() < tol, "pair {i}: rust={x} pjrt={y}");
    }
}

#[test]
fn pjrt_padded_tail_block_is_dropped() {
    if !have_artifacts() {
        return;
    }
    // 70 rows with block 64 ⇒ tail block of 6 rows padded to 64; the
    // store must contain exactly 70.
    let data = gen::generate(DataDist::Uniform01, 70, 1024, 47);
    let pipeline = Pipeline::new(cfg_pjrt(70, Strategy::Basic)).unwrap();
    pipeline.ingest(&data).unwrap();
    assert_eq!(pipeline.rows(), 70);
    assert_eq!(pipeline.store().ids().len(), 70);
}

#[test]
fn exact_artifact_matches_fallback() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::start(Path::new("artifacts")).unwrap();
    let h = engine.handle();
    let Some(meta) = h.manifest().find_exact(4).cloned() else { return };
    let x = gen::generate(DataDist::Gaussian, meta.b, meta.d, 51);
    let y = gen::generate(DataDist::Gaussian, meta.b2, meta.d, 53);
    let outs = h
        .run(
            &meta.name,
            vec![
                OwnedInput::new(x.data().to_vec(), &[meta.b, meta.d]),
                OwnedInput::new(y.data().to_vec(), &[meta.b2, meta.d]),
            ],
        )
        .unwrap();
    let want = fallback::exact_block(x.data(), y.data(), meta.b, meta.b2, meta.d, meta.p);
    assert_eq!(outs[0].len(), want.len());
    for (a, w) in outs[0].iter().zip(&want) {
        assert!((a - w).abs() < 1e-2 * (1.0 + w.abs()), "{a} vs {w}");
    }
}

#[test]
fn p6_artifacts_run() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::start(Path::new("artifacts")).unwrap();
    let h = engine.handle();
    let Some(meta) = h.manifest().find_sketch(OpKind::Sketch, 6, 64).cloned() else { return };
    let x = gen::generate(DataDist::Uniform01, meta.b, meta.d, 59);
    let spec = lpsketch::projection::ProjectionSpec::new(
        9,
        meta.k,
        lpsketch::projection::ProjectionDist::Normal,
        Strategy::Basic,
    );
    let r = spec.materialize(1, 0, meta.d).data;
    let outs = h
        .run(
            &meta.name,
            vec![
                OwnedInput::new(x.data().to_vec(), &[meta.b, meta.d]),
                OwnedInput::new(r.clone(), &[meta.d, meta.k]),
            ],
        )
        .unwrap();
    let (u_want, m_want) =
        fallback::sketch_block(x.data(), &r, meta.b, meta.d, meta.k, meta.p);
    for (a, w) in outs[0].iter().zip(&u_want) {
        assert!((a - w).abs() < 5e-2 * (1.0 + w.abs()), "u: {a} vs {w}");
    }
    // p=6 moments reach x^10 — generous f32 tolerance.
    for (a, w) in outs[1].iter().zip(&m_want) {
        assert!((a - w).abs() < 5e-2 * (1.0 + w.abs()), "m: {a} vs {w}");
    }
}
