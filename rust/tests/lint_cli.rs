//! Integration: the `lpsketch lint` exit-path contract, exercised
//! through the real executable (CARGO_BIN_EXE_lpsketch).
//!
//! The contract CI scripts rely on: findings (text lines or one
//! JSON/SARIF document) go to stdout, human diagnostics go to stderr,
//! and the exit code is 1 exactly when findings > 0.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_lpsketch"))
}

/// Materialize a throwaway source tree; `rel` paths choose rule scope.
fn plant(name: &str, files: &[(&str, &str)]) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("lpsketch_lint_cli_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    for (rel, src) in files {
        let p = dir.join(rel);
        std::fs::create_dir_all(p.parent().unwrap()).unwrap();
        std::fs::write(&p, src).unwrap();
    }
    dir
}

const CLEAN: &str = "pub fn add(a: u32, b: u32) -> u32 { a.wrapping_add(b) }\n";
const VIOLATING: &str = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";

#[test]
fn clean_tree_exits_zero_with_empty_stdout() {
    let root = plant("clean", &[("core/util.rs", CLEAN)]);
    let out = bin().args(["lint", root.to_str().unwrap()]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(out.stdout.is_empty(), "{}", String::from_utf8_lossy(&out.stdout));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("files clean"), "{stderr}");
}

#[test]
fn findings_go_to_stdout_and_exit_code_is_one() {
    let root = plant("dirty", &[("api/wire.rs", VIOLATING)]);
    let out = bin().args(["lint", root.to_str().unwrap()]).output().unwrap();
    assert_eq!(out.status.code(), Some(1));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("api/wire.rs:1: [serving-no-panic]"), "{stdout}");
    // stdout carries findings only — every line is a `file:line: [rule]`
    // record, diagnostics never leak in.
    assert!(stdout.lines().all(|l| l.contains(": [")), "{stdout}");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("finding(s)"), "{stderr}");
}

#[test]
fn json_format_reports_findings_and_count() {
    let root = plant("json", &[("api/wire.rs", VIOLATING)]);
    let out = bin()
        .args(["lint", root.to_str().unwrap(), "--format", "json"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.trim_start().starts_with('{'), "{stdout}");
    assert!(stdout.contains("\"tool\": \"pallas-lint\""), "{stdout}");
    assert!(stdout.contains("\"count\": 1"), "{stdout}");
    assert!(stdout.contains("\"rule\": \"serving-no-panic\""), "{stdout}");
}

#[test]
fn json_format_on_a_clean_tree_is_an_empty_array() {
    let root = plant("json_clean", &[("core/util.rs", CLEAN)]);
    let out = bin()
        .args(["lint", root.to_str().unwrap(), "--format", "json"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("\"count\": 0"), "{stdout}");
    assert!(stdout.contains("\"findings\": []"), "{stdout}");
}

#[test]
fn sarif_format_carries_the_code_scanning_envelope() {
    let root = plant("sarif", &[("api/wire.rs", VIOLATING)]);
    let out = bin()
        .args(["lint", root.to_str().unwrap(), "--format", "sarif"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("\"version\": \"2.1.0\""), "{stdout}");
    assert!(stdout.contains("sarif-2.1.0.json"), "{stdout}");
    assert!(stdout.contains("\"ruleId\": \"serving-no-panic\""), "{stdout}");
    assert!(stdout.contains("\"startLine\": 1"), "{stdout}");
}

#[test]
fn unknown_format_is_rejected_before_any_output() {
    let root = plant("badfmt", &[("core/util.rs", CLEAN)]);
    let out = bin()
        .args(["lint", root.to_str().unwrap(), "--format", "yaml"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(out.stdout.is_empty(), "{}", String::from_utf8_lossy(&out.stdout));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--format"), "{stderr}");
}

#[test]
fn missing_root_is_an_error() {
    let out = bin()
        .args(["lint", "/nonexistent/lpsketch_lint_root"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("not a directory"), "{stderr}");
}
