//! The pallas-lint gate: the whole `rust/src/` tree must be free of
//! un-pragma'd serving-discipline violations. This is the tier-1 /
//! CI enforcement point for the conventions the analyzer encodes —
//! see `rust/src/analysis/` and the README's "Static analysis" section.

use std::path::Path;

use lpsketch::analysis;

fn src_root() -> &'static Path {
    Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/rust/src"))
}

#[test]
fn tree_is_clean() {
    let findings = analysis::analyze_tree(src_root()).expect("walking rust/src");
    assert!(
        findings.is_empty(),
        "pallas-lint found {} violation(s):\n{}\n\
         fix the site, or (only when provably infallible) add\n\
         `// pallas-lint: allow(<rule>) -- <reason>` on or above the line",
        findings.len(),
        findings.iter().map(|f| f.render()).collect::<Vec<_>>().join("\n")
    );
}

#[test]
fn gate_actually_walked_the_crate() {
    // A refactor that moves the sources (or a walker bug) must not let
    // the gate pass vacuously.
    let files = analysis::count_rs_files(src_root()).expect("walking rust/src");
    assert!(files >= 30, "expected the full crate, saw only {files} .rs files");
}

#[test]
fn gate_catches_a_planted_violation() {
    // End-to-end sanity: the same entry point the gate uses does fail
    // on a violating file under a scoped path.
    let bad = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
    let findings = analysis::analyze_source("api/wire.rs", bad);
    assert!(
        findings.iter().any(|f| f.rule == analysis::SERVING_NO_PANIC),
        "{findings:?}"
    );
}
