//! The pallas-lint gate: the whole `rust/src/` tree must be free of
//! un-pragma'd serving-discipline violations. This is the tier-1 /
//! CI enforcement point for the conventions the analyzer encodes —
//! see `rust/src/analysis/` and the README's "Static analysis" section.

use std::path::Path;

use lpsketch::analysis;

fn src_root() -> &'static Path {
    Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/rust/src"))
}

#[test]
fn tree_is_clean() {
    let findings = analysis::analyze_tree(src_root()).expect("walking rust/src");
    assert!(
        findings.is_empty(),
        "pallas-lint found {} violation(s):\n{}\n\
         fix the site, or (only when provably infallible) add\n\
         `// pallas-lint: allow(<rule>) -- <reason>` on or above the line",
        findings.len(),
        findings.iter().map(|f| f.render()).collect::<Vec<_>>().join("\n")
    );
}

#[test]
fn gate_actually_walked_the_crate() {
    // A refactor that moves the sources (or a walker bug) must not let
    // the gate pass vacuously.
    let files = analysis::count_rs_files(src_root()).expect("walking rust/src");
    assert!(files >= 30, "expected the full crate, saw only {files} .rs files");
}

#[test]
fn gate_catches_a_planted_violation() {
    // End-to-end sanity: the same entry point the gate uses does fail
    // on a violating file under a scoped path.
    let bad = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
    let findings = analysis::analyze_source("api/wire.rs", bad);
    assert!(
        findings.iter().any(|f| f.rule == analysis::SERVING_NO_PANIC),
        "{findings:?}"
    );
}

#[test]
fn gate_catches_planted_v2_violations() {
    // One planted violation per structural rule, so a regression in the
    // token-tree or dataflow layers cannot quietly blind the gate while
    // `tree_is_clean` keeps passing vacuously.
    let plants: &[(&str, &str, &str)] = &[
        (
            "baselines/exact.rs",
            "pub unsafe fn k(p: *const f32) -> f32 { *p }\n",
            analysis::UNSAFE_CONTRACT,
        ),
        (
            "coordinator/scheduler.rs",
            "fn f(&self) {\n\
             let segs = self.segments.write_recover();\n\
             let serial = self.compaction.lock_recover();\n\
             }\n",
            analysis::LOCK_ORDER,
        ),
        (
            "knn/mod.rs",
            "pub fn serve(&self) {\n\
             let g = self.store.shards[0].read_recover();\n\
             }\n",
            analysis::SNAPSHOT_DISCIPLINE,
        ),
        (
            "coordinator/persist.rs",
            "fn fill(n: usize) -> Vec<u8> {\n\
             vec![0u8; n]\n\
             }\n\
             fn load(b: &[u8]) -> Vec<u8> {\n\
             let n = u32::from_le_bytes([b[0], b[1], b[2], b[3]]) as usize;\n\
             fill(n)\n\
             }\n",
            analysis::LEN_BEFORE_ALLOC,
        ),
        (
            "coordinator/segfile.rs",
            "pub const SEG_VERSION: u32 = 3;\n\
             fn read_seg(f: &mut File) -> anyhow::Result<Seg> {\n\
             let version = r_u32(f)?;\n\
             ensure!(version >= 1 && version <= 3, \"segfile version\");\n\
             if version >= 2 { read_zones(f)?; }\n\
             if version >= 3 { read_checksums(f)?; }\n\
             Ok(Seg::default())\n\
             }\n",
            analysis::CODEC_VERSION_EXHAUSTIVE,
        ),
    ];
    for (rel, src, rule) in plants {
        let findings = analysis::analyze_source(rel, src);
        assert!(
            findings.iter().any(|f| f.rule == *rule),
            "{rel}: expected {rule}, got {findings:?}"
        );
    }
}
