//! Vendored minimal `anyhow` shim — the subset of the real crate's API
//! this repository uses, implemented over `std` only so the workspace
//! builds with no registry access.
//!
//! Covered surface: [`Error`], [`Result`], the [`anyhow!`], [`bail!`]
//! and [`ensure!`] macros, and the [`Context`] extension trait
//! (`.context(..)` / `.with_context(..)` on `Result`). Error sources are
//! flattened into the display string rather than kept as a chain — the
//! repo only ever formats errors with `{}` / `{:?}`.

use std::fmt::{self, Debug, Display};

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-backed error value. Like the real `anyhow::Error`, it does
/// NOT implement `std::error::Error` (that keeps the blanket
/// `From<E: std::error::Error>` conversion coherent).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(&e)
    }
}

/// `.context(..)` / `.with_context(..)` on fallible values.
pub trait Context<T, E> {
    /// Wrap the error with a message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    /// Wrap the error with a lazily evaluated message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Construct an [`Error`] from a format string (or any displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_ctx(s: &str) -> Result<i32> {
        let n: i32 = s.parse::<i32>().context("parsing int")?;
        ensure!(n > 0, "expected positive, got {n}");
        Ok(n)
    }

    #[test]
    fn conversions_and_macros() {
        assert_eq!(parse_ctx("5").unwrap(), 5);
        let e = parse_ctx("x").unwrap_err();
        assert!(e.to_string().starts_with("parsing int:"), "{e}");
        let e = parse_ctx("-3").unwrap_err();
        assert_eq!(e.to_string(), "expected positive, got -3");
        let val = 7;
        let e = anyhow!("custom {val:?}");
        assert_eq!(e.to_string(), "custom 7");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<i32, std::num::ParseIntError> = "3".parse();
        let mut called = false;
        let got = ok
            .with_context(|| {
                called = true;
                "ctx"
            })
            .unwrap();
        assert_eq!(got, 3);
        assert!(!called, "context closure must not run on Ok");
    }
}
