//! Stub of the PJRT/XLA binding surface `runtime/executor.rs` compiles
//! against. The container image does not ship the native PJRT plugin,
//! so this crate provides the same types and signatures but fails fast
//! (with a clear error) the moment a real client is requested.
//!
//! The failure point is `PjRtClient::cpu()` — everything in the
//! pipeline gates PJRT behind `Engine::start`, which calls it, so with
//! this stub the engine refuses to start and every caller falls back to
//! the pure-rust kernels (the default path; `use_pjrt` is opt-in and
//! the PJRT integration tests skip themselves when `artifacts/` is
//! absent). Swapping in the real bindings is a Cargo.toml-only change.

use std::borrow::Borrow;
use std::fmt::{self, Display};

/// Error type of every stub operation.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what} is unavailable — this build uses the vendored PJRT stub \
         (link the real xla bindings to execute AOT artifacts)"
    ))
}

/// PJRT client handle (never constructible through the stub).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (text format).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// A compiled executable (never constructible through the stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer produced by an execution.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A host literal (tensor value).
#[derive(Clone)]
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal(()))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_fails_fast_with_clear_message() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"), "{err}");
    }

    #[test]
    fn literal_reshape_is_shape_only() {
        let lit = Literal::vec1(&[1.0, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
    }
}
